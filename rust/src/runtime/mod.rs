//! PJRT runtime: artifact manifest + compiled-executable cache.
//!
//! Python is build-time only; this module is how the Rust request path
//! executes the AOT-lowered L2/L1 compute.

pub mod artifacts;
pub mod client;

pub use artifacts::{default_artifact_dir, ArtifactSpec, Manifest, TensorSpec};
pub use client::{HostTensor, Runtime};
