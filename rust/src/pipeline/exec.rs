//! Real-execution pipeline: stage threads running AOT HLO artifacts via
//! PJRT, connected by channels — the L3 hot path with *real numerics*.
//!
//! Device-timing comes from the simulator (DESIGN.md substitution table);
//! this path proves the three layers compose: Pallas kernels → JAX layer
//! graphs → HLO text → PJRT executables driven by the Rust coordinator,
//! with the paper's §II-B data-partition strategy (static tensors —
//! graph blocks, weights — pre-loaded per stage; only activations flow).
//!
//! `PjRtClient` is `!Send`, so each stage thread owns its own client and
//! compiled executables; activations cross stages as host `Vec<f32>`
//! (the stand-in for the PCIe P2P hop).

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::runtime::{HostTensor, Runtime};

/// Where a kernel argument comes from.
#[derive(Debug, Clone)]
pub enum ArgSource {
    /// Pre-loaded static tensor (graph structure, weights).
    Static(HostTensor),
    /// The activation flowing through the pipeline (fed once per
    /// inference; may appear multiple times, e.g. self-attention q=k=v).
    Dynamic,
}

/// One kernel invocation inside a stage.
#[derive(Debug, Clone)]
pub struct KernelBinding {
    pub artifact: String,
    pub args: Vec<ArgSource>,
}

/// A pipeline stage: an ordered kernel chain executed by one worker.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub name: String,
    pub kernels: Vec<KernelBinding>,
}

/// Results of a real pipeline run.
#[derive(Debug)]
pub struct RealRunReport {
    pub outputs: Vec<HostTensor>,
    /// Wall-clock makespan (s).
    pub wall_time: f64,
    /// Whole-run throughput (inferences/s) on this CPU host.
    pub throughput: f64,
    /// Per-stage busy seconds (compile time excluded).
    pub stage_busy: Vec<f64>,
}

/// Execute `inputs` through the staged pipeline, one thread per stage.
pub fn run_pipeline(
    artifact_dir: PathBuf,
    stages: Vec<StageSpec>,
    inputs: Vec<HostTensor>,
) -> Result<RealRunReport> {
    ensure!(!stages.is_empty(), "no stages");
    ensure!(!inputs.is_empty(), "no inputs");
    let n_stages = stages.len();
    let n_inf = inputs.len();

    // Channel chain: ingress -> s0 -> s1 -> ... -> egress.
    let mut senders: Vec<mpsc::Sender<HostTensor>> = Vec::with_capacity(n_stages);
    let mut receivers: Vec<mpsc::Receiver<HostTensor>> = Vec::with_capacity(n_stages + 1);
    for _ in 0..=n_stages {
        let (tx, rx) = mpsc::channel::<HostTensor>();
        senders.push(tx);
        receivers.push(rx);
    }
    let egress = receivers.pop().unwrap();
    let ingress = senders.remove(0);
    // senders[i] now feeds stage i+1's input; receivers[i] is stage i's input.

    let mut handles = Vec::with_capacity(n_stages);
    for (si, spec) in stages.into_iter().enumerate() {
        let rx = receivers.remove(0);
        let tx = senders.remove(0);
        let dir = artifact_dir.clone();
        handles.push(std::thread::spawn(move || -> Result<f64> {
            let mut rt = Runtime::new(&dir)?;
            // Warm the executable cache before the stream starts.
            for k in &spec.kernels {
                rt.load(&k.artifact)?;
            }
            let mut busy = 0.0f64;
            while let Ok(mut act) = rx.recv() {
                let t0 = Instant::now();
                for k in &spec.kernels {
                    let args: Vec<HostTensor> = k
                        .args
                        .iter()
                        .map(|a| match a {
                            ArgSource::Static(t) => t.clone(),
                            ArgSource::Dynamic => act.clone(),
                        })
                        .collect();
                    act = rt.execute(&k.artifact, &args)?;
                }
                busy += t0.elapsed().as_secs_f64();
                tx.send(act)
                    .map_err(|_| anyhow!("stage {si} ({}): downstream hung up", spec.name))?;
            }
            Ok(busy)
        }));
    }
    drop(senders);
    drop(receivers);

    let t0 = Instant::now();
    let feeder = std::thread::spawn(move || {
        for t in inputs {
            if ingress.send(t).is_err() {
                break;
            }
        }
    });

    let mut outputs = Vec::with_capacity(n_inf);
    for _ in 0..n_inf {
        outputs.push(egress.recv().map_err(|_| anyhow!("pipeline died before egress"))?);
    }
    let wall_time = t0.elapsed().as_secs_f64();
    feeder.join().map_err(|_| anyhow!("feeder panicked"))?;

    let mut stage_busy = Vec::with_capacity(n_stages);
    for h in handles {
        stage_busy.push(h.join().map_err(|_| anyhow!("stage panicked"))??);
    }

    Ok(RealRunReport {
        outputs,
        wall_time,
        throughput: n_inf as f64 / wall_time,
        stage_busy,
    })
}
