//! Streaming-pipeline discrete-event simulator — the "measure the
//! schedule on the testbed" stand-in.
//!
//! Given a timed [`Schedule`], streams `n` inferences through the stages:
//! stage `s` starts inference `t` when it has finished inference `t-1`
//! *and* stage `s-1` has delivered inference `t`. This reproduces
//! steady-state behaviour (throughput → 1/bottleneck), warmup/drain
//! effects, and the Fig-4 conflict guard: an FPGA stage whose ingress and
//! egress share its PCIe port delays its first iteration by one CPU-FPGA
//! communication cycle (§II-B), after which the pipeline's serialized
//! stage schedule keeps the transfers separated.

use crate::devices::{CommModel, DeviceType};
use crate::scheduler::energy::PowerTable;
use crate::scheduler::pipeline_def::{Schedule, Stage};
use crate::workload::Workload;

/// Measured results of streaming `n` inferences through a schedule.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub inferences: usize,
    /// Total wall time from first ingress to last egress (s).
    pub makespan: f64,
    /// Steady-state throughput measured over the post-warmup window
    /// (inferences/s).
    pub throughput: f64,
    /// Mean end-to-end latency per inference (s).
    pub mean_latency: f64,
    /// Total energy over the run (J).
    pub energy: f64,
    /// Energy per inference (J).
    pub energy_per_inf: f64,
    /// Per-stage busy fraction of the makespan.
    pub stage_utilization: Vec<f64>,
}

impl SimReport {
    pub fn energy_efficiency(&self) -> f64 {
        1.0 / self.energy_per_inf
    }
}

/// The pipeline streaming simulator.
pub struct PipelineSim<'a> {
    pub power: &'a PowerTable,
    pub comm: &'a CommModel,
}

impl<'a> PipelineSim<'a> {
    pub fn new(power: &'a PowerTable, comm: &'a CommModel) -> Self {
        PipelineSim { power, comm }
    }

    /// Stream `n` inferences of `wl` through `sched`.
    pub fn run(&self, wl: &Workload, sched: &Schedule, n: usize) -> SimReport {
        assert!(n >= 2, "need at least 2 inferences to measure a period");
        let stages = &sched.stages;
        let s = stages.len();
        let times: Vec<f64> = stages.iter().map(Stage::total_time).collect();

        // Fig-4 guard: first-iteration offset for FPGA stages with both
        // ingress and egress on their PCIe ports.
        let guard: Vec<f64> = stages
            .iter()
            .map(|st| {
                if st.dev == DeviceType::Fpga
                    && st.comm_in_time > 0.0
                    && st.comm_out_time > 0.0
                {
                    let bytes = wl.transfer_bytes_into(st.first);
                    self.comm.conflict_guard_delay(bytes)
                } else {
                    0.0
                }
            })
            .collect();

        // finish[s] holds the finish time of the stage's latest inference.
        let mut finish_prev_inf = vec![0.0f64; s];
        let mut first_start = vec![f64::INFINITY; s];
        let mut busy = vec![0.0f64; s];
        let mut latencies = Vec::with_capacity(n);
        let mut completion = Vec::with_capacity(n);

        for t in 0..n {
            let mut ready_from_prev = 0.0f64; // ingress availability
            let mut start_of_first_stage = 0.0;
            for (si, &dt) in times.iter().enumerate() {
                let mut start = ready_from_prev.max(finish_prev_inf[si]);
                if t == 0 {
                    start += guard[si];
                }
                let end = start + dt;
                if si == 0 {
                    start_of_first_stage = start;
                }
                first_start[si] = first_start[si].min(start);
                busy[si] += dt;
                finish_prev_inf[si] = end;
                ready_from_prev = end;
            }
            latencies.push(ready_from_prev - start_of_first_stage);
            completion.push(ready_from_prev);
        }

        let makespan = *completion.last().unwrap();
        // Steady-state window: skip the first ~S inferences (pipeline fill).
        let warm = s.min(n - 1);
        let window = completion[n - 1] - completion[warm.saturating_sub(1)];
        let throughput = if window > 0.0 {
            (n - warm) as f64 / window
        } else {
            f64::INFINITY
        };

        // Energy: activity per inference × n + static power over makespan.
        let mut activity_total = 0.0;
        let mut static_total = 0.0;
        for st in stages {
            let kernel_times: Vec<f64> = wl.kernels[st.first..=st.last]
                .iter()
                .map(|k| {
                    // Apportion exec time over kernels by their FLOP share
                    // (power differs per kernel on the FPGA).
                    let total_flops: f64 =
                        wl.kernels[st.first..=st.last].iter().map(|x| x.kind.flops()).sum();
                    st.exec_time * k.kind.flops() / total_flops.max(1.0)
                })
                .collect();
            let exec_energy: f64 = wl.kernels[st.first..=st.last]
                .iter()
                .zip(&kernel_times)
                .map(|(k, &t)| self.power.dynamic_power(&k.kind, st.dev) * t)
                .sum();
            let xfer_energy = self.power.transfer_power(st.dev)
                * (st.comm_in_time + st.comm_out_time);
            activity_total += st.n as f64 * (exec_energy + xfer_energy) * n as f64;
            static_total += st.n as f64 * self.power.static_power(st.dev) * makespan;
        }
        let energy = activity_total + static_total;

        SimReport {
            inferences: n,
            makespan,
            throughput,
            mean_latency: latencies.iter().sum::<f64>() / n as f64,
            energy,
            energy_per_inf: energy / n as f64,
            stage_utilization: busy.iter().map(|b| b / makespan).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Objective, SystemSpec};
    use crate::devices::{GroundTruth, Interconnect};
    use crate::perfmodel::OracleModels;
    use crate::scheduler::dp::DpScheduler;
    use crate::workload::{gnn, Dataset};

    fn setup() -> (SystemSpec, GroundTruth) {
        let s = SystemSpec::paper_testbed(Interconnect::Pcie4);
        let g = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        (s, g)
    }

    #[test]
    fn steady_state_throughput_matches_analytic_period() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let sched_builder = DpScheduler::new(&s, &oracle);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let sched = sched_builder.schedule(&wl, Objective::Performance);
        let sim = PipelineSim::new(&sched_builder.power, &sched_builder.comm);
        let report = sim.run(&wl, &sched, 200);
        let analytic = sched.throughput();
        let rel = (report.throughput - analytic).abs() / analytic;
        assert!(rel < 0.02, "sim {} vs analytic {analytic}", report.throughput);
    }

    #[test]
    fn latency_at_least_sum_of_stages() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let b = DpScheduler::new(&s, &oracle);
        let wl = gnn::gin_workload(&Dataset::synthetic2(), 2, 128, 2);
        let sched = b.schedule(&wl, Objective::Performance);
        let report = PipelineSim::new(&b.power, &b.comm).run(&wl, &sched, 50);
        assert!(report.mean_latency >= sched.latency() * 0.999);
    }

    #[test]
    fn bottleneck_stage_has_highest_utilization() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let b = DpScheduler::new(&s, &oracle);
        let wl = gnn::gcn_workload(&Dataset::ogbn_products(), 2, 128);
        let sched = b.schedule(&wl, Objective::Performance);
        if sched.stages.len() < 2 {
            return; // single stage: trivially true
        }
        let report = PipelineSim::new(&b.power, &b.comm).run(&wl, &sched, 300);
        let bottleneck_idx = sched
            .stages
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_time().partial_cmp(&b.1.total_time()).unwrap())
            .unwrap()
            .0;
        let max_util_idx = report
            .stage_utilization
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(bottleneck_idx, max_util_idx);
    }

    #[test]
    fn sim_energy_close_to_analytic_estimate() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let b = DpScheduler::new(&s, &oracle);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let sched = b.schedule(&wl, Objective::Energy);
        let report = PipelineSim::new(&b.power, &b.comm).run(&wl, &sched, 500);
        let rel = (report.energy_per_inf - sched.energy_per_inf).abs() / sched.energy_per_inf;
        // Warmup/drain and FLOP-proportional power apportioning introduce
        // small deviations; steady state must agree closely.
        assert!(rel < 0.1, "sim {} vs analytic {}", report.energy_per_inf, sched.energy_per_inf);
    }

    #[test]
    fn more_inferences_amortize_warmup() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let b = DpScheduler::new(&s, &oracle);
        let wl = gnn::gcn_workload(&Dataset::synthetic3(), 2, 128);
        let sched = b.schedule(&wl, Objective::Performance);
        let sim = PipelineSim::new(&b.power, &b.comm);
        let short = sim.run(&wl, &sched, 5);
        let long = sim.run(&wl, &sched, 500);
        // Effective whole-run throughput (n/makespan) improves with n.
        assert!(long.inferences as f64 / long.makespan >= short.inferences as f64 / short.makespan);
    }
}
