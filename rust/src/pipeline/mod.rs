//! Pipeline execution: the discrete-event streaming simulator (timing and
//! energy measurement over the device substrate) and the real-execution
//! pipeline (PJRT artifacts on stage threads — real numerics).

pub mod exec;
pub mod sim;

pub use exec::{run_pipeline, ArgSource, KernelBinding, RealRunReport, StageSpec};
pub use sim::{PipelineSim, SimReport};
