//! The checked-in scenario zoo.
//!
//! Sixteen manifests: the four canonical serving scenarios the
//! experiments module has always built ([`multi_stream`],
//! [`skewed_pair`], [`energy_slo`], [`deadline`] — the
//! `crate::experiments::*_scenario` builders now *delegate here*, so the
//! manifest format is the single source of truth and the round-trip is
//! bit-identical), plus ten dynamic stressors exercising the arrival
//! curves and mid-run perturbations the static 86-case grid cannot
//! express, plus two fleet-routing scenarios ([`fleet_balanced`],
//! [`fleet_skewed`]) sized for the sharded fleet layer
//! ([`crate::fleet`]). [`all`] returns the full zoo; every entry has a
//! checked-in twin under `scenarios/` that CI tree-compares against
//! these builders.

use super::{Arrival, BudgetCfg, Phase, ScenarioManifest, StreamCfg, SystemCfg, WorkloadCfg};
use crate::config::{Interconnect, Objective};
use crate::engine::{MigrationMode, Perturbation, StreamSlo};

/// The traffic-forecast GCN lane every canonical scenario draws from: a
/// 1M-intersection road network whose interaction-graph edge count is
/// the drift axis.
fn traffic_gcn(edges: u64) -> WorkloadCfg {
    WorkloadCfg::Gcn {
        code: "TF".to_string(),
        graph: "traffic".to_string(),
        vertices: 1_000_000,
        edges,
        feature_len: 200,
        degree_skew: 0.2,
        layers: 2,
        hidden: 128,
    }
}

/// A mid-size GIN lane (synthetic product-graph numbers) for mixed-fleet
/// scenarios.
fn products_gin() -> WorkloadCfg {
    WorkloadCfg::Gin {
        code: "PR".to_string(),
        graph: "products".to_string(),
        vertices: 400_000,
        edges: 1_200_000,
        feature_len: 100,
        degree_skew: 0.6,
        layers: 3,
        hidden: 64,
        mlp_layers: 2,
    }
}

fn phase(workload: WorkloadCfg, count: usize) -> Phase {
    Phase { workload, count }
}

fn poisson(rate: f64) -> Arrival {
    Arrival::Poisson { rate }
}

/// All canonical streams serve performance-objective lanes; QoS
/// differentiation lives in the [`StreamSlo`], not the objective.
fn stream(
    name: &str,
    arrival: Arrival,
    seed: u64,
    phases: Vec<Phase>,
    slo: StreamSlo,
) -> StreamCfg {
    StreamCfg {
        name: name.to_string(),
        objective: Objective::Performance,
        seed,
        arrival,
        phases,
        slo,
    }
}

/// The paper testbed's inventory (3 FPGAs + 2 GPUs, PCIe 4).
fn paper_system() -> SystemCfg {
    SystemCfg { n_fpga: 3, n_gpu: 2, interconnect: Interconnect::Pcie4 }
}

// ---------------------------------------------------------------------
// The four canonical scenarios, parameterized exactly like their
// `crate::experiments` ancestors (same workloads, rates, seed offsets,
// stream order) so the delegation round-trip is bit-identical.

/// Manifest twin of `experiments::multi_stream_scenario`: recurring
/// day-cycle drift on a GCN lane plus a regime-cycling transformer lane.
pub fn multi_stream(cycles: usize, per_phase: usize, seed: u64) -> ScenarioManifest {
    assert!(cycles >= 1 && per_phase >= 1);
    let day_edges: [u64; 6] =
        [2_000_000, 20_000_000, 150_000_000, 50_000_000, 150_000_000, 8_000_000];
    let mut gcn_phases = Vec::new();
    for _ in 0..cycles {
        for &edges in &day_edges {
            gcn_phases.push(phase(traffic_gcn(edges), per_phase));
        }
    }
    let regimes: [(u64, u64); 4] = [(2048, 512), (4096, 1024), (8192, 1024), (2048, 512)];
    let mut tf_phases = Vec::new();
    for _ in 0..cycles {
        for &(seq, window) in &regimes {
            tf_phases.push(phase(WorkloadCfg::Transformer { seq, window, layers: 8 }, per_phase));
        }
    }
    ScenarioManifest {
        name: "multi-stream".to_string(),
        description: "Canonical two-lane serving: day-cycle GCN drift + transformer regimes"
            .to_string(),
        system: paper_system(),
        streams: vec![
            stream("gcn-traffic", poisson(40.0), seed, gcn_phases, StreamSlo::default()),
            stream("swin-transformer", poisson(20.0), seed + 1, tf_phases, StreamSlo::default()),
        ],
        budget: None,
        perturbations: vec![],
        telemetry: false,
    }
}

/// Manifest twin of `experiments::skewed_pair_scenario`: equal offered
/// totals, phase-reversed halves — the repartitioning stressor.
pub fn skewed_pair(per_phase: usize, seed: u64) -> ScenarioManifest {
    assert!(per_phase >= 1);
    let heavy = traffic_gcn(150_000_000);
    let light = traffic_gcn(2_000_000);
    let front = vec![phase(heavy.clone(), per_phase), phase(light.clone(), per_phase)];
    let back = vec![phase(light, per_phase), phase(heavy, per_phase)];
    ScenarioManifest {
        name: "skewed-pair".to_string(),
        description: "Phase-reversed demand skew: static leases are wrong in both halves"
            .to_string(),
        system: paper_system(),
        streams: vec![
            stream("front-loaded", poisson(10.0), seed, front, StreamSlo::default()),
            stream("back-loaded", poisson(10.0), seed + 1, back, StreamSlo::default()),
        ],
        budget: None,
        perturbations: vec![],
        telemetry: false,
    }
}

/// Manifest twin of `experiments::energy_slo_scenario` (three QoS
/// classes under a power cap); the budget matches the 250 W cap the
/// acceptance tests pair it with.
pub fn energy_slo(per_phase: usize, seed: u64) -> ScenarioManifest {
    assert!(per_phase >= 1);
    let streams = vec![
        stream(
            "latency-critical",
            poisson(25.0),
            seed,
            vec![phase(traffic_gcn(2_000_000), 5 * per_phase)],
            StreamSlo::target(0.100, 3.0),
        ),
        stream(
            "bulk-analytics",
            poisson(5.0),
            seed + 1,
            vec![phase(traffic_gcn(150_000_000), 2 * per_phase)],
            StreamSlo::best_effort(2.0),
        ),
        stream(
            "background-embeddings",
            poisson(12.0),
            seed + 2,
            vec![phase(traffic_gcn(20_000_000), 3 * per_phase)],
            StreamSlo::best_effort(1.0),
        ),
    ];
    ScenarioManifest {
        name: "energy-slo".to_string(),
        description: "Three QoS classes under a 250 W budget: defer strictly below priority"
            .to_string(),
        system: paper_system(),
        streams,
        budget: Some(BudgetCfg { cap_watts: 250.0, window: 0.25 }),
        perturbations: vec![],
        telemetry: false,
    }
}

/// Manifest twin of `experiments::deadline_scenario`: an overloaded hard
/// deadline lane (preempt override) among best-effort skew and a
/// drain-pinned bulk lane.
pub fn deadline(per_phase: usize, seed: u64) -> ScenarioManifest {
    assert!(per_phase >= 1);
    let heavy = traffic_gcn(150_000_000);
    let light = traffic_gcn(2_000_000);
    let interactive_slo = StreamSlo::target(0.150, 3.0)
        .with_deadline(0.250)
        .with_migration(MigrationMode::Preempt { min_remaining: 0.005 });
    let streams = vec![
        stream(
            "deadline-interactive",
            poisson(40.0),
            seed,
            vec![phase(light.clone(), 6 * per_phase)],
            interactive_slo,
        ),
        stream(
            "front-loaded",
            poisson(10.0),
            seed + 1,
            vec![phase(heavy.clone(), per_phase), phase(light.clone(), per_phase)],
            StreamSlo::best_effort(2.0),
        ),
        stream(
            "back-loaded",
            poisson(10.0),
            seed + 2,
            vec![phase(light, per_phase), phase(heavy.clone(), per_phase)],
            StreamSlo::best_effort(2.0),
        ),
        stream(
            "bulk-drain",
            poisson(4.0),
            seed + 3,
            vec![phase(heavy, per_phase)],
            StreamSlo::best_effort(1.0).with_migration(MigrationMode::Drain),
        ),
    ];
    ScenarioManifest {
        name: "deadline".to_string(),
        description: "Overloaded hard-deadline lane among best-effort skew and a drain-pinned bulk"
            .to_string(),
        system: paper_system(),
        streams,
        budget: None,
        perturbations: vec![],
        telemetry: false,
    }
}

// ---------------------------------------------------------------------
// The dynamic stressors: arrival curves and perturbations the static
// grid cannot express. Request counts stay small — the whole zoo is a
// CI-speed regression net, not a load generator.

/// A flash crowd slams an overloaded deadline lane: queue-ahead pricing
/// must shed hopeless arrivals on arrival and keep the queue bounded.
pub fn flash_crowd() -> ScenarioManifest {
    let burst =
        Arrival::FlashCrowd { base_rate: 10.0, peak_rate: 200.0, start: 0.2, duration: 0.3 };
    let interactive_slo = StreamSlo::target(0.150, 3.0)
        .with_deadline(0.250)
        .with_migration(MigrationMode::Preempt { min_remaining: 0.005 });
    ScenarioManifest {
        name: "flash-crowd".to_string(),
        description: "200/s burst into a 250 ms deadline lane: early shedding bounds the queue"
            .to_string(),
        system: paper_system(),
        streams: vec![
            stream(
                "deadline-interactive",
                burst,
                31,
                vec![phase(traffic_gcn(2_000_000), 50)],
                interactive_slo,
            ),
            stream(
                "bulk-drain",
                poisson(4.0),
                32,
                vec![phase(traffic_gcn(150_000_000), 6)],
                StreamSlo::best_effort(1.0).with_migration(MigrationMode::Drain),
            ),
        ],
        budget: None,
        perturbations: vec![],
        telemetry: false,
    }
}

/// A raised-cosine day curve against a steady transformer lane: demand
/// tracking must follow the swell without thrashing at the trough.
pub fn diurnal() -> ScenarioManifest {
    let day = Arrival::Diurnal { base_rate: 5.0, peak_rate: 60.0, period: 2.0 };
    ScenarioManifest {
        name: "diurnal".to_string(),
        description: "Raised-cosine GCN day curve beside a steady transformer lane".to_string(),
        system: paper_system(),
        streams: vec![
            stream(
                "gcn-diurnal",
                day,
                41,
                vec![phase(traffic_gcn(20_000_000), 40)],
                StreamSlo::target(0.200, 2.0),
            ),
            stream(
                "txf-steady",
                poisson(10.0),
                42,
                vec![phase(WorkloadCfg::Transformer { seq: 2048, window: 512, layers: 8 }, 12)],
                StreamSlo::best_effort(1.0),
            ),
        ],
        budget: None,
        perturbations: vec![],
        telemetry: false,
    }
}

/// An MMPP-style burst chain (calm/burst states on a fixed dwell)
/// against a trickle of heavy bulk work.
pub fn mmpp_burst() -> ScenarioManifest {
    let bursts = Arrival::Mmpp { rates: vec![4.0, 80.0], dwell: 0.5 };
    ScenarioManifest {
        name: "mmpp-burst".to_string(),
        description: "Two-state burst chain (4/s calm, 80/s burst) beside heavy bulk".to_string(),
        system: paper_system(),
        streams: vec![
            stream(
                "bursty-gnn",
                bursts,
                51,
                vec![phase(traffic_gcn(2_000_000), 40)],
                StreamSlo::best_effort(2.0),
            ),
            stream(
                "bulk",
                poisson(4.0),
                52,
                vec![phase(traffic_gcn(150_000_000), 5)],
                StreamSlo::best_effort(1.0),
            ),
        ],
        budget: None,
        perturbations: vec![],
        telemetry: false,
    }
}

/// The skewed pair, then two devices die mid-run: adaptive policies must
/// re-apportion the shrunken pool at the cut.
pub fn device_failure() -> ScenarioManifest {
    let mut m = skewed_pair(4, 61);
    m.name = "device-failure".to_string();
    m.description =
        "Skewed pair loses one FPGA and one GPU at t=0.6 s: re-apportion or stall".to_string();
    m.perturbations = vec![Perturbation::device_cut(0.6, 1, 1)];
    m
}

/// The energy/SLO class mix, then the power cap halves mid-run: deferral
/// pressure doubles and priority order must hold.
pub fn budget_cut() -> ScenarioManifest {
    let mut m = energy_slo(2, 71);
    m.name = "budget-cut".to_string();
    m.description = "Energy/SLO classes; the 250 W cap halves at t=1 s".to_string();
    m.perturbations = vec![Perturbation::budget_scale(1.0, 0.5)];
    m
}

/// A comfortably-served deadline lane whose deadline collapses to 200 ms
/// mid-run: shedding must start exactly when the bound tightens.
pub fn slo_tighten() -> ScenarioManifest {
    let interactive_slo = StreamSlo::target(0.200, 3.0).with_deadline(10.0);
    ScenarioManifest {
        name: "slo-tighten".to_string(),
        description: "A 10 s deadline collapses to 200 ms at t=0.5 s: shedding starts mid-run"
            .to_string(),
        system: paper_system(),
        streams: vec![
            stream(
                "tightening-lane",
                poisson(40.0),
                81,
                vec![phase(traffic_gcn(2_000_000), 30)],
                interactive_slo,
            ),
            stream(
                "bulk",
                poisson(4.0),
                82,
                vec![phase(traffic_gcn(150_000_000), 4)],
                StreamSlo::best_effort(1.0),
            ),
        ],
        budget: None,
        perturbations: vec![Perturbation::slo_tighten(0.5, 0, 1.0, 0.02)],
        telemetry: false,
    }
}

/// Four lanes on a 2F+1G pool: more streams than devices, so every lease
/// is a weighted time slice.
pub fn oversubscribed() -> ScenarioManifest {
    let sizes: [u64; 4] = [2_000_000, 8_000_000, 20_000_000, 50_000_000];
    let streams = sizes
        .iter()
        .enumerate()
        .map(|(i, &edges)| {
            stream(
                &format!("lane-{i}"),
                poisson(8.0),
                91 + i as u64,
                vec![phase(traffic_gcn(edges), 8)],
                StreamSlo::best_effort(1.0 + i as f64),
            )
        })
        .collect();
    ScenarioManifest {
        name: "oversubscribed".to_string(),
        description: "Four lanes on a 2F+1G pool: weighted time-sliced leases only".to_string(),
        system: SystemCfg { n_fpga: 2, n_gpu: 1, interconnect: Interconnect::Pcie4 },
        streams,
        budget: None,
        perturbations: vec![],
        telemetry: false,
    }
}

/// GCN + GIN + transformer lanes sharing one pool: the heterogeneous
/// mix the lease apportionment must price across model families.
pub fn mixed_fleet() -> ScenarioManifest {
    ScenarioManifest {
        name: "mixed-fleet".to_string(),
        description: "GCN, GIN, and transformer lanes share one paper-testbed pool".to_string(),
        system: paper_system(),
        streams: vec![
            stream(
                "gcn-lane",
                poisson(20.0),
                101,
                vec![phase(traffic_gcn(20_000_000), 12)],
                StreamSlo::target(0.200, 2.0),
            ),
            stream(
                "gin-lane",
                poisson(12.0),
                102,
                vec![phase(products_gin(), 10)],
                StreamSlo::best_effort(1.5),
            ),
            stream(
                "txf-lane",
                poisson(10.0),
                103,
                vec![phase(WorkloadCfg::Transformer { seq: 4096, window: 1024, layers: 8 }, 8)],
                StreamSlo::best_effort(1.0),
            ),
        ],
        budget: None,
        perturbations: vec![],
        telemetry: false,
    }
}

/// The canonical two-lane mix on a CXL 3.0 fabric — the interconnect
/// axis of the paper grid, in scenario form.
pub fn cxl_fleet() -> ScenarioManifest {
    let mut m = multi_stream(1, 3, 111);
    m.name = "cxl-fleet".to_string();
    m.description =
        "Canonical two-lane mix on CXL 3.0: cheaper hops, different frontier".to_string();
    m.system.interconnect = Interconnect::Cxl3;
    m
}

/// Everything at once: a flash crowd into a deadline lane *while* the
/// power cap halves mid-burst.
pub fn flash_crowd_budget() -> ScenarioManifest {
    let mut m = flash_crowd();
    m.name = "flash-crowd-budget".to_string();
    m.description =
        "Flash crowd into a deadline lane while the power cap halves mid-burst".to_string();
    m.streams[0].seed = 121;
    m.streams[1].seed = 122;
    m.budget = Some(BudgetCfg { cap_watts: 250.0, window: 0.25 });
    m.perturbations = vec![Perturbation::budget_scale(0.35, 0.5)];
    m
}

// ---------------------------------------------------------------------
// The fleet-routing scenarios: stream mixes shaped for the sharded
// fleet layer (`crate::fleet`) rather than a single engine.

/// Eight near-equal GCN lanes on a 12F+8G pool: a four-shard fleet
/// splits it into even 3F+2G slices and the router spreads two lanes
/// per shard — the fleet-throughput baseline (`benches/fleet.rs` scales
/// its request counts up and measures 1-shard vs 4-shard wall clock).
/// No deadlines, so no shard ever degrades and no migration triggers.
pub fn fleet_balanced() -> ScenarioManifest {
    let streams = (0..8)
        .map(|i| {
            stream(
                &format!("lane-{i}"),
                poisson(15.0),
                131 + i as u64,
                vec![phase(traffic_gcn(20_000_000), 10)],
                StreamSlo::default(),
            )
        })
        .collect();
    ScenarioManifest {
        name: "fleet-balanced".to_string(),
        description: "Eight near-equal GCN lanes across a four-shard 12F+8G fleet".to_string(),
        system: SystemCfg { n_fpga: 12, n_gpu: 8, interconnect: Interconnect::Pcie4 },
        streams,
        budget: None,
        perturbations: vec![],
        telemetry: false,
    }
}

/// An overloaded 80/s deadline lane co-locating with bulk on one slice
/// of a two-shard paper-testbed fleet: the hot shard's shed rate clears
/// the hysteresis bound while the other shard idles along, forcing at
/// least one cross-shard migration (pinned in `rust/tests/fleet.rs`).
pub fn fleet_skewed() -> ScenarioManifest {
    let hot_slo = StreamSlo::target(0.150, 3.0)
        .with_deadline(0.250)
        .with_migration(MigrationMode::Preempt { min_remaining: 0.005 });
    let streams = vec![
        stream(
            "deadline-hot",
            poisson(80.0),
            141,
            vec![phase(traffic_gcn(2_000_000), 40)],
            hot_slo,
        ),
        stream(
            "bulk-a",
            poisson(4.0),
            142,
            vec![phase(traffic_gcn(150_000_000), 6)],
            StreamSlo::best_effort(2.0),
        ),
        stream(
            "bulk-b",
            poisson(4.0),
            143,
            vec![phase(traffic_gcn(150_000_000), 6)],
            StreamSlo::best_effort(2.0),
        ),
        stream(
            "light",
            poisson(10.0),
            144,
            vec![phase(traffic_gcn(2_000_000), 10)],
            StreamSlo::best_effort(1.0),
        ),
    ];
    ScenarioManifest {
        name: "fleet-skewed".to_string(),
        description: "Overloaded deadline lane among bulk on a two-shard fleet: must migrate"
            .to_string(),
        system: paper_system(),
        streams,
        budget: None,
        perturbations: vec![],
        telemetry: false,
    }
}

/// The whole zoo, canonical scenarios first. Every entry has a
/// checked-in twin at `scenarios/<file_name>` (tree-compared in CI).
pub fn all() -> Vec<ScenarioManifest> {
    vec![
        multi_stream(2, 4, 9),
        skewed_pair(5, 11),
        energy_slo(4, 17),
        deadline(8, 23),
        flash_crowd(),
        diurnal(),
        mmpp_burst(),
        device_failure(),
        budget_cut(),
        slo_tighten(),
        oversubscribed(),
        mixed_fleet(),
        cxl_fleet(),
        flash_crowd_budget(),
        fleet_balanced(),
        fleet_skewed(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::ScenarioManifest;
    use std::collections::BTreeSet;

    #[test]
    fn the_zoo_has_sixteen_unique_buildable_scenarios() {
        let zoo = all();
        assert_eq!(zoo.len(), 16);
        let names: BTreeSet<&str> = zoo.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names.len(), 16, "scenario names must be unique");
        for m in &zoo {
            let built = m.build().unwrap_or_else(|e| panic!("{} fails to build: {e:#}", m.name));
            assert!(!built.streams.is_empty());
            let total: usize = built.streams.iter().map(|s| s.trace.len()).sum();
            assert!(total >= 10, "{} is too small to exercise anything", m.name);
        }
    }

    #[test]
    fn every_manifest_round_trips_through_its_pretty_form() {
        for m in all() {
            let back = ScenarioManifest::parse_str(&m.to_pretty_string())
                .unwrap_or_else(|e| panic!("{}: {e:#}", m.name));
            assert_eq!(back, m, "{} drifts through serialization", m.name);
        }
    }

    #[test]
    fn stressors_carry_their_advertised_dynamics() {
        assert!(matches!(flash_crowd().streams[0].arrival, Arrival::FlashCrowd { .. }));
        assert_eq!(flash_crowd().streams[0].slo.deadline, Some(0.250));
        assert!(matches!(diurnal().streams[0].arrival, Arrival::Diurnal { .. }));
        assert!(matches!(mmpp_burst().streams[0].arrival, Arrival::Mmpp { .. }));
        assert_eq!(device_failure().perturbations.len(), 1);
        assert!(budget_cut().budget.is_some());
        assert_eq!(slo_tighten().perturbations.len(), 1);
        assert_eq!(cxl_fleet().system.interconnect, Interconnect::Cxl3);
        let over = oversubscribed();
        assert!(over.streams.len() > over.system.n_fpga + over.system.n_gpu);
        assert!(flash_crowd_budget().budget.is_some());
        assert_eq!(flash_crowd_budget().perturbations.len(), 1);
        let balanced = fleet_balanced();
        assert_eq!(balanced.streams.len(), 8);
        assert_eq!((balanced.system.n_fpga, balanced.system.n_gpu), (12, 8));
        let skewed = fleet_skewed();
        assert_eq!(skewed.streams[0].slo.deadline, Some(0.250));
        assert!(matches!(skewed.streams[0].slo.migration, Some(MigrationMode::Preempt { .. })));
    }
}
