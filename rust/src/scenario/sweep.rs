//! The declarative sweep runner: a scenario×policy grid with a
//! Pareto-front report.
//!
//! The paper's table 86-cases-wide is a *static* sweep: one scheduler
//! per cell, winner circled. This is the serving analogue: every
//! [`ScenarioManifest`] in the zoo crossed with every serving [`Policy`]
//! (static leases, adaptive-drain, adaptive-preempt, deadline-tuned),
//! each cell one full engine run, each row scored on throughput,
//! energy, throughput-per-joule, worst p99, attainment floors, and shed
//! rate. [`SweepReport::render`] marks the per-scenario winner and the
//! Pareto-non-dominated cells ([`crate::metrics::pareto_front`]);
//! [`SweepReport::adaptive_scoreboard`] re-derives the paper's "optimal
//! in 77 of 86 cases" headline on the zoo — CI fails when the static
//! baseline starts beating the adaptive default.

use anyhow::Result;

use super::{catalog, ScenarioManifest};
use crate::coordinator::MultiStreamReport;
use crate::engine::EngineConfig;
use crate::experiments::run_multi_stream_with;
use crate::metrics::{self, Table};
use crate::telemetry::{Recorder, Snapshot};

/// The serving policies the grid crosses every scenario with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Frozen demand-proportional leases
    /// ([`crate::engine::EngineConfigBuilder::static_leases`]) — the
    /// baseline the adaptive policies must beat.
    Static,
    /// The engine default: online re-partitioning, drain-mode handoffs.
    AdaptiveDrain,
    /// Adaptive with mid-slot preemption on a 2 s horizon.
    AdaptivePreempt,
    /// The deadline-tuned preemptive policy (1 s horizon), as
    /// `experiments::deadline_config`.
    Deadline,
}

impl Policy {
    pub const ALL: [Policy; 4] =
        [Policy::Static, Policy::AdaptiveDrain, Policy::AdaptivePreempt, Policy::Deadline];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Static => "static",
            Policy::AdaptiveDrain => "adaptive-drain",
            Policy::AdaptivePreempt => "adaptive-preempt",
            Policy::Deadline => "deadline",
        }
    }

    /// Everything except the frozen-lease baseline re-partitions online.
    pub fn is_adaptive(&self) -> bool {
        !matches!(self, Policy::Static)
    }

    pub fn engine_config(&self) -> EngineConfig {
        match self {
            Policy::Static => EngineConfig::builder().static_leases().build(),
            Policy::AdaptiveDrain => EngineConfig::default(),
            Policy::AdaptivePreempt => EngineConfig::builder().preemptive(2.0).build(),
            Policy::Deadline => EngineConfig::builder().preemptive(1.0).build(),
        }
    }
}

/// One grid cell: scenario × policy, scored.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub scenario: String,
    pub policy: Policy,
    /// Aggregate completed-inference throughput (inf/s).
    pub throughput: f64,
    /// Total energy charged across streams (J).
    pub energy: f64,
    pub throughput_per_joule: f64,
    /// Worst per-stream p99 latency (s).
    pub worst_p99: f64,
    /// Floor over streams of p99-target attainment.
    pub min_slo_attainment: f64,
    /// Floor over streams of deadline attainment.
    pub min_deadline_attainment: f64,
    pub completed: usize,
    pub sheds: usize,
    pub offered: usize,
    pub perturbations_applied: usize,
    /// The engine's hot-path counter snapshot for this cell (events
    /// popped per kind, heap high-water, cache traffic — see
    /// [`crate::telemetry::Snapshot`]). Always populated; the counters
    /// are on regardless of whether a trace recorder is attached.
    pub telemetry: Snapshot,
    /// Trace records captured by the cell's timeline recorder; 0 unless
    /// the manifest set [`ScenarioManifest::telemetry`].
    pub trace_records: usize,
}

impl SweepCell {
    pub fn from_report(
        scenario: &str,
        policy: Policy,
        offered: usize,
        r: &MultiStreamReport,
    ) -> SweepCell {
        let worst_p99 = r.streams.iter().map(|s| s.report.p99_latency).fold(0.0, f64::max);
        let min_slo = r.streams.iter().map(|s| s.report.slo_attainment).fold(1.0, f64::min);
        let min_dl = r.streams.iter().map(|s| s.report.deadline_attainment).fold(1.0, f64::min);
        SweepCell {
            scenario: scenario.to_string(),
            policy,
            throughput: r.aggregate_throughput,
            energy: r.total_energy,
            throughput_per_joule: r.throughput_per_joule,
            worst_p99,
            min_slo_attainment: min_slo,
            min_deadline_attainment: min_dl,
            completed: r.total_completed,
            sheds: r.streams.iter().map(|s| s.report.shed).sum(),
            offered,
            perturbations_applied: r.engine.perturbations_applied,
            telemetry: r.engine.telemetry.clone(),
            trace_records: 0,
        }
    }

    /// Scalar ranking score: *useful* throughput — aggregate throughput
    /// discounted by the worst stream's SLO and deadline attainment, so
    /// a policy cannot win the cell by starving its QoS lanes.
    pub fn score(&self) -> f64 {
        self.throughput * self.min_slo_attainment * self.min_deadline_attainment
    }

    /// Shed requests as a fraction of offered load.
    pub fn shed_rate(&self) -> f64 {
        self.sheds as f64 / self.offered as f64
    }

    /// Request conservation: every offered request settled exactly once,
    /// as a completion or a shed. The sweep's per-cell invariant.
    pub fn conserved(&self) -> bool {
        self.completed + self.sheds == self.offered
    }

    /// The cell's coordinates on the Pareto axes (all maximized):
    /// throughput, efficiency, both attainment floors, negated p99.
    pub fn pareto_point(&self) -> Vec<f64> {
        vec![
            self.throughput,
            self.throughput_per_joule,
            self.min_slo_attainment,
            self.min_deadline_attainment,
            -self.worst_p99,
        ]
    }
}

/// Run one scenario under one policy: lower the manifest, fold its
/// budget + perturbation script into the policy's engine config, serve.
/// When the manifest opts into telemetry, a timeline recorder rides the
/// run and the cell reports how many trace records it captured.
pub fn run_cell(m: &ScenarioManifest, policy: Policy) -> Result<SweepCell> {
    let built = m.build()?;
    let offered: usize = built.streams.iter().map(|s| s.trace.len()).sum();
    let mut cfg = built.apply(policy.engine_config());
    let recorder = built.telemetry.then(Recorder::timeline);
    if let Some(rec) = &recorder {
        cfg.recorder = Some(rec.clone());
    }
    let report = run_multi_stream_with(&built.system, &built.streams, cfg);
    let mut cell = SweepCell::from_report(&m.name, policy, offered, &report);
    if let Some(rec) = &recorder {
        cell.trace_records = rec.drain().len();
    }
    Ok(cell)
}

/// Cross every manifest with every policy, in order.
pub fn run_grid(manifests: &[ScenarioManifest], policies: &[Policy]) -> Result<SweepReport> {
    let mut cells = Vec::new();
    for m in manifests {
        for &p in policies {
            cells.push(run_cell(m, p)?);
        }
    }
    Ok(SweepReport { cells })
}

/// The full-zoo grid: every catalog scenario × every policy.
pub fn run_zoo() -> Result<SweepReport> {
    run_grid(&catalog::all(), &Policy::ALL)
}

/// [`run_grid`] fanned out over the indexed worker pool
/// ([`crate::util::pool::run_indexed`]): every (manifest, policy) cell
/// is one full engine run with no shared mutable state, so up to
/// `threads` workers claim cells concurrently. Results are collected
/// **by cell index**, never completion order, so the report — cell
/// order, scores, rendering — is byte-identical to [`run_grid`]'s
/// (pinned by a test); only wall time differs. Any cell's error fails
/// the whole grid, first grid-order error wins, exactly as the serial
/// path's early return reports it.
pub fn run_grid_parallel(
    manifests: &[ScenarioManifest],
    policies: &[Policy],
    threads: usize,
) -> Result<SweepReport> {
    let jobs: Vec<(&ScenarioManifest, Policy)> =
        manifests.iter().flat_map(|m| policies.iter().map(move |&p| (m, p))).collect();
    let results = crate::util::pool::run_indexed(jobs.len(), threads, |i| {
        let (m, p) = jobs[i];
        run_cell(m, p)
    });
    let mut cells = Vec::with_capacity(results.len());
    for r in results {
        cells.push(r?);
    }
    Ok(SweepReport { cells })
}

/// [`run_zoo`] across every available core — what the CI sweep smoke
/// and the `scenario_sweep` example run.
pub fn run_zoo_parallel() -> Result<SweepReport> {
    run_grid_parallel(&catalog::all(), &Policy::ALL, crate::util::pool::default_threads())
}

/// The finished grid, ready to rank and render.
#[derive(Debug, Clone, Default)]
pub struct SweepReport {
    pub cells: Vec<SweepCell>,
}

impl SweepReport {
    /// Scenario names in first-appearance order.
    pub fn scenarios(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for c in &self.cells {
            if !out.contains(&c.scenario.as_str()) {
                out.push(&c.scenario);
            }
        }
        out
    }

    pub fn cells_for(&self, scenario: &str) -> Vec<&SweepCell> {
        self.cells.iter().filter(|c| c.scenario == scenario).collect()
    }

    /// The cell with the best [`SweepCell::score`] in a scenario; ties
    /// go to the earliest policy in grid order.
    pub fn winner(&self, scenario: &str) -> Option<&SweepCell> {
        let mut best: Option<&SweepCell> = None;
        for c in self.cells_for(scenario) {
            if best.map_or(true, |b| c.score() > b.score()) {
                best = Some(c);
            }
        }
        best
    }

    /// The paper's headline, re-derived on the zoo: in how many
    /// scenarios does the best adaptive policy beat or tie the static
    /// baseline on score? Returns `(wins_or_ties, scenarios)` — the
    /// repo's "77 of 86".
    pub fn adaptive_scoreboard(&self) -> (usize, usize) {
        let mut wins = 0;
        let scenarios = self.scenarios();
        for sc in &scenarios {
            if self.best_adaptive_score(sc) >= self.best_static_score(sc) {
                wins += 1;
            }
        }
        (wins, scenarios.len())
    }

    /// Best score among adaptive policies in a scenario
    /// (`NEG_INFINITY` when none ran).
    pub fn best_adaptive_score(&self, scenario: &str) -> f64 {
        self.cells_for(scenario)
            .iter()
            .filter(|c| c.policy.is_adaptive())
            .map(|c| c.score())
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// The static baseline's score in a scenario (`NEG_INFINITY` when it
    /// did not run).
    pub fn best_static_score(&self, scenario: &str) -> f64 {
        self.cells_for(scenario)
            .iter()
            .filter(|c| c.policy == Policy::Static)
            .map(|c| c.score())
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Render the grid: one row per cell, `*` marking Pareto-non-
    /// dominated cells within the scenario, `win` the score winner, plus
    /// the adaptive-vs-static scoreboard footer.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "scenario", "policy", "inf/s", "J", "inf/J", "p99 ms", "slo", "dline", "shed", "mark",
        ]);
        for sc in self.scenarios() {
            let cells = self.cells_for(sc);
            let points: Vec<Vec<f64>> = cells.iter().map(|c| c.pareto_point()).collect();
            let front = metrics::pareto_front(&points);
            let winner = self.winner(sc).map(|w| w.policy);
            for (i, c) in cells.iter().enumerate() {
                let mut mark = String::new();
                if front.contains(&i) {
                    mark.push('*');
                }
                if winner == Some(c.policy) {
                    mark.push_str(" win");
                }
                t.row(vec![
                    c.scenario.clone(),
                    c.policy.name().to_string(),
                    format!("{:.2}", c.throughput),
                    format!("{:.1}", c.energy),
                    format!("{:.4}", c.throughput_per_joule),
                    format!("{:.1}", c.worst_p99 * 1e3),
                    format!("{:.3}", c.min_slo_attainment),
                    format!("{:.3}", c.min_deadline_attainment),
                    format!("{:.3}", c.shed_rate()),
                    mark,
                ]);
            }
        }
        let (wins, n) = self.adaptive_scoreboard();
        let footer =
            format!("adaptive wins or ties the static baseline in {wins} of {n} scenarios");
        format!("{}\n{footer}\n", t.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_configs_match_their_names() {
        assert!(Policy::Static.engine_config().repartition.is_none());
        assert!(!Policy::Static.is_adaptive());
        for p in [Policy::AdaptiveDrain, Policy::AdaptivePreempt, Policy::Deadline] {
            assert!(p.engine_config().repartition.is_some(), "{} must repartition", p.name());
            assert!(p.is_adaptive());
        }
        let names: Vec<&str> = Policy::ALL.iter().map(|p| p.name()).collect();
        assert_eq!(names, ["static", "adaptive-drain", "adaptive-preempt", "deadline"]);
    }

    #[test]
    fn a_tiny_grid_runs_and_ranks() {
        // One small scenario × two policies keeps this a unit test; the
        // seeded-subset grid lives in the integration suite.
        let m = catalog::skewed_pair(2, 11);
        let report = run_grid(&[m], &[Policy::Static, Policy::AdaptiveDrain]).expect("grid runs");
        assert_eq!(report.cells.len(), 2);
        assert_eq!(report.scenarios(), ["skewed-pair"]);
        for c in &report.cells {
            let label = format!("{}/{}", c.scenario, c.policy.name());
            assert!(c.conserved(), "{label}: {} + {} != {}", c.completed, c.sheds, c.offered);
            assert!(c.throughput > 0.0);
            assert!(c.score().is_finite());
        }
        let w = report.winner("skewed-pair").expect("winner exists");
        assert!(w.score() >= report.cells[0].score());
        let rendered = report.render();
        assert!(rendered.contains("skewed-pair"));
        assert!(rendered.contains("win"));
        assert!(rendered.contains("of 1 scenarios"));
        for c in &report.cells {
            // Counters ride every cell; traces only opt-in manifests.
            assert!(c.telemetry.events_total() > 0);
            assert_eq!(c.trace_records, 0, "no recorder without the manifest flag");
        }
    }

    #[test]
    fn parallel_grid_is_byte_identical_to_serial() {
        let manifests = vec![catalog::skewed_pair(2, 11), catalog::mmpp_burst()];
        let policies = [Policy::Static, Policy::AdaptiveDrain];
        let serial = run_grid(&manifests, &policies).expect("serial grid runs");
        for threads in [1, 4] {
            let par = run_grid_parallel(&manifests, &policies, threads).expect("parallel runs");
            assert_eq!(par.render(), serial.render(), "threads={threads}");
            assert_eq!(par.cells.len(), serial.cells.len());
            for (a, b) in par.cells.iter().zip(&serial.cells) {
                assert_eq!((a.scenario.as_str(), a.policy), (b.scenario.as_str(), b.policy));
                assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
                assert_eq!(a.energy.to_bits(), b.energy.to_bits());
                assert_eq!((a.completed, a.sheds, a.offered), (b.completed, b.sheds, b.offered));
                assert_eq!(a.telemetry, b.telemetry, "hot-path counters must match");
                assert_eq!(a.trace_records, b.trace_records);
            }
        }
    }

    #[test]
    fn a_telemetry_cell_captures_a_trace() {
        let mut m = catalog::skewed_pair(2, 11);
        m.telemetry = true;
        let cell = run_cell(&m, Policy::AdaptiveDrain).expect("cell runs");
        assert!(cell.trace_records > 0, "the manifest opt-in must attach a recorder");
        // Every offered request pops exactly one arrival event.
        assert_eq!(cell.telemetry.popped("arrival") as usize, cell.offered);
    }
}
