//! The scenario zoo — declarative serving scenarios and their manifest
//! format (DESIGN.md §Scenarios).
//!
//! The paper's evaluation is a *static* grid: 86 workload×system cells,
//! each measured once under each scheduler. This module is the serving
//! analogue of that study, made declarative and dynamic: a
//! [`ScenarioManifest`] is a small JSON document (parsed with
//! [`crate::util::json`] — no external deps) that names
//!
//! * an **arrival process** per stream ([`Arrival`]): constant-rate
//!   Poisson (bit-identical to
//!   [`crate::coordinator::generate_trace`]), a diurnal rate curve, a
//!   flash crowd, or an MMPP-style burst chain;
//! * a **stream mix** ([`StreamCfg`]): GNN / transformer / mixed lanes
//!   drawn from the [`crate::workload`] builders, each with its own
//!   objective, seed, and [`StreamSlo`] class;
//! * a **system** ([`SystemCfg`]): device pool sizes and interconnect,
//!   lowered onto the paper testbed's device configs;
//! * optional **budget** ([`BudgetCfg`]) and mid-run **perturbations**
//!   ([`Perturbation`]): device cuts, budget cuts, SLO tightening.
//!
//! [`ScenarioManifest::build`] lowers the manifest to engine vocabulary
//! ([`BuiltScenario`]); [`sweep`] runs a scenario×policy grid over the
//! zoo ([`catalog`]) and reports the winner per cell — the repo's
//! regression net for the paper's "optimal in 77 of 86 cases" headline.
//!
//! The codec is **strict**: unknown keys are rejected, so a typo in a
//! checked-in manifest fails loudly in CI instead of silently changing
//! the scenario.

pub mod catalog;
pub mod sweep;

use std::collections::BTreeMap;
use std::f64::consts::TAU;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{Interconnect, Objective, SystemSpec};
use crate::coordinator::{Request, StreamSpec};
use crate::engine::{
    EnergyBudget, EngineConfig, MigrationMode, Perturbation, PerturbationKind, StreamSlo,
};
use crate::util::json::{self, Json, KeyPath};
use crate::util::Rng;
use crate::workload::{gnn, transformer, Dataset, Workload};

/// One declarative serving scenario: everything
/// [`crate::experiments::run_multi_stream_with`] needs except the policy
/// under test, which the sweep supplies per grid cell.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioManifest {
    /// Kebab-case scenario id; the checked-in file is
    /// `scenarios/<name with '-'→'_'>.json` ([`Self::file_name`]).
    pub name: String,
    pub description: String,
    pub system: SystemCfg,
    pub streams: Vec<StreamCfg>,
    /// `Some` puts the run under a per-window joule budget.
    pub budget: Option<BudgetCfg>,
    /// Scripted mid-run mutations, in manifest order.
    pub perturbations: Vec<Perturbation>,
    /// Opt into engine trace recording ([`crate::telemetry`]): sweep
    /// runners attach a timeline recorder to every cell of this
    /// scenario. Off by default — serialized only when set, so existing
    /// manifests round-trip bit-identically.
    pub telemetry: bool,
}

/// Device pool of a scenario. Device *configs* (clocks, power curves)
/// stay the paper testbed's; manifests vary inventory and interconnect —
/// the same axes the paper's 86-case grid sweeps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystemCfg {
    pub n_fpga: usize,
    pub n_gpu: usize,
    pub interconnect: Interconnect,
}

/// Energy budget as a power cap: `cap_watts` × `window` joules refill
/// each window (see [`EnergyBudget::from_power_cap`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BudgetCfg {
    pub cap_watts: f64,
    pub window: f64,
}

/// One request lane: an arrival process over a phase sequence of
/// workloads, plus objective and SLO class.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamCfg {
    pub name: String,
    pub objective: Objective,
    /// RNG seed for the arrival recurrence (one draw per request).
    pub seed: u64,
    pub arrival: Arrival,
    /// Consecutive workload phases; requests take phase workloads in
    /// order, mirroring [`crate::coordinator::generate_trace`]'s
    /// `(workload, count)` pairs.
    pub phases: Vec<Phase>,
    pub slo: StreamSlo,
}

/// `count` consecutive requests carrying the same workload.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    pub workload: WorkloadCfg,
    pub count: usize,
}

/// A workload named by its generator parameters, so a manifest is
/// self-contained: graph workloads spell out the [`Dataset`] fields,
/// transformers their geometry.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadCfg {
    Gcn {
        code: String,
        graph: String,
        vertices: u64,
        edges: u64,
        feature_len: u64,
        degree_skew: f64,
        layers: usize,
        hidden: u64,
    },
    Gin {
        code: String,
        graph: String,
        vertices: u64,
        edges: u64,
        feature_len: u64,
        degree_skew: f64,
        layers: usize,
        hidden: u64,
        mlp_layers: usize,
    },
    Transformer { seq: u64, window: u64, layers: usize },
}

/// A stream's arrival process. Timestamps come from the thinning-free
/// recurrence `t += Exp(1)/rate_at(t)` — one RNG draw per request, so
/// the constant-rate case reproduces
/// [`crate::coordinator::generate_trace`] bit for bit and every process
/// is deterministic per seed.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// Constant-rate Poisson (req/s).
    Poisson { rate: f64 },
    /// Raised-cosine day curve: `base` at phase 0, `peak` half a period
    /// in, period `period` seconds.
    Diurnal { base_rate: f64, peak_rate: f64, period: f64 },
    /// Step burst: `base_rate` everywhere except `[start, start+duration)`,
    /// where the rate jumps to `peak_rate`.
    FlashCrowd { base_rate: f64, peak_rate: f64, start: f64, duration: f64 },
    /// Markov-modulated-style burst chain with deterministic state
    /// dwell: the rate cycles through `rates`, holding each for `dwell`
    /// seconds.
    Mmpp { rates: Vec<f64>, dwell: f64 },
}

impl Arrival {
    /// Instantaneous arrival rate (req/s) at time `t`.
    pub fn rate_at(&self, t: f64) -> f64 {
        match self {
            Arrival::Poisson { rate } => *rate,
            Arrival::Diurnal { base_rate, peak_rate, period } => {
                let phase = (t / period).fract();
                base_rate + (peak_rate - base_rate) * 0.5 * (1.0 - (TAU * phase).cos())
            }
            Arrival::FlashCrowd { base_rate, peak_rate, start, duration } => {
                if t >= *start && t < start + duration {
                    *peak_rate
                } else {
                    *base_rate
                }
            }
            Arrival::Mmpp { rates, dwell } => rates[(t / dwell) as usize % rates.len()],
        }
    }

    /// Draw `n` arrival timestamps: `t += -(1 - u).ln() / rate_at(t)`
    /// with one `gen_f64` per request — the exact recurrence (and RNG
    /// draw budget) of [`crate::coordinator::generate_trace`], evaluated
    /// at the piecewise rate.
    pub fn times(&self, n: usize, seed: u64) -> Vec<f64> {
        self.validate();
        let mut rng = Rng::seed_from_u64(seed);
        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            t += -(1.0 - rng.gen_f64()).ln() / self.rate_at(t);
            out.push(t);
        }
        out
    }

    /// Panic on degenerate parameters (the engine's eager-validation
    /// stance; the JSON codec surfaces shape errors as `Result`s, value
    /// errors fail here).
    pub fn validate(&self) {
        fn positive(x: f64, what: &str) {
            assert!(x > 0.0 && x.is_finite(), "{what} must be positive and finite, got {x}");
        }
        match self {
            Arrival::Poisson { rate } => positive(*rate, "poisson rate"),
            Arrival::Diurnal { base_rate, peak_rate, period } => {
                positive(*base_rate, "diurnal base_rate");
                positive(*peak_rate, "diurnal peak_rate");
                positive(*period, "diurnal period");
            }
            Arrival::FlashCrowd { base_rate, peak_rate, start, duration } => {
                positive(*base_rate, "flash-crowd base_rate");
                positive(*peak_rate, "flash-crowd peak_rate");
                positive(*duration, "flash-crowd duration");
                assert!(*start >= 0.0 && start.is_finite(), "flash-crowd start must be >= 0");
            }
            Arrival::Mmpp { rates, dwell } => {
                assert!(!rates.is_empty(), "mmpp needs at least one rate state");
                for r in rates {
                    positive(*r, "mmpp rate");
                }
                positive(*dwell, "mmpp dwell");
            }
        }
    }

    fn to_json(&self) -> Json {
        match self {
            Arrival::Poisson { rate } => {
                obj_from(vec![("kind", jstr("poisson")), ("rate", jnum(*rate))])
            }
            Arrival::Diurnal { base_rate, peak_rate, period } => obj_from(vec![
                ("kind", jstr("diurnal")),
                ("base_rate", jnum(*base_rate)),
                ("peak_rate", jnum(*peak_rate)),
                ("period", jnum(*period)),
            ]),
            Arrival::FlashCrowd { base_rate, peak_rate, start, duration } => obj_from(vec![
                ("kind", jstr("flash-crowd")),
                ("base_rate", jnum(*base_rate)),
                ("peak_rate", jnum(*peak_rate)),
                ("start", jnum(*start)),
                ("duration", jnum(*duration)),
            ]),
            Arrival::Mmpp { rates, dwell } => obj_from(vec![
                ("kind", jstr("mmpp")),
                ("rates", Json::Arr(rates.iter().map(|r| jnum(*r)).collect())),
                ("dwell", jnum(*dwell)),
            ]),
        }
    }

    fn from_json(j: &Json, at: &KeyPath) -> Result<Arrival> {
        let m = obj(j, at)?;
        let kind = str_field(m, "kind", at)?;
        Ok(match kind {
            "poisson" => {
                check_keys(m, &["kind", "rate"], at)?;
                Arrival::Poisson { rate: num_field(m, "rate", at)? }
            }
            "diurnal" => {
                check_keys(m, &["base_rate", "kind", "peak_rate", "period"], at)?;
                Arrival::Diurnal {
                    base_rate: num_field(m, "base_rate", at)?,
                    peak_rate: num_field(m, "peak_rate", at)?,
                    period: num_field(m, "period", at)?,
                }
            }
            "flash-crowd" => {
                check_keys(m, &["base_rate", "duration", "kind", "peak_rate", "start"], at)?;
                Arrival::FlashCrowd {
                    base_rate: num_field(m, "base_rate", at)?,
                    peak_rate: num_field(m, "peak_rate", at)?,
                    start: num_field(m, "start", at)?,
                    duration: num_field(m, "duration", at)?,
                }
            }
            "mmpp" => {
                check_keys(m, &["dwell", "kind", "rates"], at)?;
                let mut rates = Vec::new();
                for (i, r) in arr_field(m, "rates", at)?.iter().enumerate() {
                    let msg = || format!("{}: must be a number", at.key("rates").index(i));
                    rates.push(r.as_f64().with_context(msg)?);
                }
                Arrival::Mmpp { rates, dwell: num_field(m, "dwell", at)? }
            }
            other => bail!("{}: unknown arrival kind '{other}'", at.key("kind")),
        })
    }
}

impl WorkloadCfg {
    /// Lower to a [`Workload`] via the same builders the experiments
    /// use, so a manifest round-trips the hard-coded scenarios exactly.
    pub fn build(&self) -> Workload {
        match self {
            WorkloadCfg::Gcn { layers, hidden, .. } => {
                let ds = self.dataset().expect("gcn carries a dataset");
                gnn::gcn_workload(&ds, *layers, *hidden)
            }
            WorkloadCfg::Gin { layers, hidden, mlp_layers, .. } => {
                let ds = self.dataset().expect("gin carries a dataset");
                gnn::gin_workload(&ds, *layers, *hidden, *mlp_layers)
            }
            WorkloadCfg::Transformer { seq, window, layers } => {
                transformer::transformer_workload(*seq, *window, *layers)
            }
        }
    }

    fn dataset(&self) -> Option<Dataset> {
        match self {
            WorkloadCfg::Gcn { code, graph, vertices, edges, feature_len, degree_skew, .. }
            | WorkloadCfg::Gin { code, graph, vertices, edges, feature_len, degree_skew, .. } => {
                Some(Dataset::new(code, graph, *vertices, *edges, *feature_len, *degree_skew))
            }
            WorkloadCfg::Transformer { .. } => None,
        }
    }

    fn kind_name(&self) -> &'static str {
        match self {
            WorkloadCfg::Gcn { .. } => "gcn",
            WorkloadCfg::Gin { .. } => "gin",
            WorkloadCfg::Transformer { .. } => "transformer",
        }
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![("kind", jstr(self.kind_name()))];
        match self {
            WorkloadCfg::Gcn { code, graph, vertices, edges, feature_len, degree_skew, .. }
            | WorkloadCfg::Gin { code, graph, vertices, edges, feature_len, degree_skew, .. } => {
                pairs.push(("code", jstr(code)));
                pairs.push(("graph", jstr(graph)));
                pairs.push(("vertices", jint(*vertices)));
                pairs.push(("edges", jint(*edges)));
                pairs.push(("feature_len", jint(*feature_len)));
                pairs.push(("degree_skew", jnum(*degree_skew)));
            }
            WorkloadCfg::Transformer { .. } => {}
        }
        match self {
            WorkloadCfg::Gcn { layers, hidden, .. } => {
                pairs.push(("layers", jint(*layers as u64)));
                pairs.push(("hidden", jint(*hidden)));
            }
            WorkloadCfg::Gin { layers, hidden, mlp_layers, .. } => {
                pairs.push(("layers", jint(*layers as u64)));
                pairs.push(("hidden", jint(*hidden)));
                pairs.push(("mlp_layers", jint(*mlp_layers as u64)));
            }
            WorkloadCfg::Transformer { seq, window, layers } => {
                pairs.push(("seq", jint(*seq)));
                pairs.push(("window", jint(*window)));
                pairs.push(("layers", jint(*layers as u64)));
            }
        }
        obj_from(pairs)
    }

    fn from_json(j: &Json, at: &KeyPath) -> Result<WorkloadCfg> {
        let m = obj(j, at)?;
        let graph_keys = [
            "code", "degree_skew", "edges", "feature_len", "graph", "hidden", "kind", "layers",
            "vertices",
        ];
        let kind = str_field(m, "kind", at)?;
        Ok(match kind {
            "gcn" => {
                check_keys(m, &graph_keys, at)?;
                WorkloadCfg::Gcn {
                    code: str_field(m, "code", at)?.to_string(),
                    graph: str_field(m, "graph", at)?.to_string(),
                    vertices: int_field(m, "vertices", at)?,
                    edges: int_field(m, "edges", at)?,
                    feature_len: int_field(m, "feature_len", at)?,
                    degree_skew: num_field(m, "degree_skew", at)?,
                    layers: int_field(m, "layers", at)? as usize,
                    hidden: int_field(m, "hidden", at)?,
                }
            }
            "gin" => {
                let mut gin_keys = graph_keys.to_vec();
                gin_keys.push("mlp_layers");
                check_keys(m, &gin_keys, at)?;
                WorkloadCfg::Gin {
                    code: str_field(m, "code", at)?.to_string(),
                    graph: str_field(m, "graph", at)?.to_string(),
                    vertices: int_field(m, "vertices", at)?,
                    edges: int_field(m, "edges", at)?,
                    feature_len: int_field(m, "feature_len", at)?,
                    degree_skew: num_field(m, "degree_skew", at)?,
                    layers: int_field(m, "layers", at)? as usize,
                    hidden: int_field(m, "hidden", at)?,
                    mlp_layers: int_field(m, "mlp_layers", at)? as usize,
                }
            }
            "transformer" => {
                check_keys(m, &["kind", "layers", "seq", "window"], at)?;
                WorkloadCfg::Transformer {
                    seq: int_field(m, "seq", at)?,
                    window: int_field(m, "window", at)?,
                    layers: int_field(m, "layers", at)? as usize,
                }
            }
            other => bail!("{}: unknown workload kind '{other}'", at.key("kind")),
        })
    }
}

impl Phase {
    fn to_json(&self) -> Json {
        obj_from(vec![("count", jint(self.count as u64)), ("workload", self.workload.to_json())])
    }

    fn from_json(j: &Json, at: &KeyPath) -> Result<Phase> {
        let m = obj(j, at)?;
        check_keys(m, &["count", "workload"], at)?;
        let count = int_field(m, "count", at)? as usize;
        if count == 0 {
            bail!("{}: phase count must be >= 1", at.key("count"));
        }
        let workload = WorkloadCfg::from_json(field(m, "workload", at)?, &at.key("workload"))?;
        Ok(Phase { workload, count })
    }
}

impl StreamCfg {
    /// Materialize the lane: draw arrival times, stamp requests in phase
    /// order (ids are trace positions, as in
    /// [`crate::coordinator::generate_trace`]), attach objective + SLO.
    pub fn build(&self) -> Result<StreamSpec> {
        if self.phases.is_empty() {
            bail!("stream '{}' has no phases", self.name);
        }
        let n: usize = self.phases.iter().map(|p| p.count).sum();
        let times = self.arrival.times(n, self.seed);
        let mut trace = Vec::with_capacity(n);
        for phase in &self.phases {
            let wl = phase.workload.build();
            for _ in 0..phase.count {
                let arrival = times[trace.len()];
                trace.push(Request { id: trace.len(), arrival, workload: wl.clone() });
            }
        }
        Ok(StreamSpec::new(self.name.clone(), self.objective, trace).with_slo(self.slo.clone()))
    }

    fn to_json(&self) -> Json {
        obj_from(vec![
            ("name", jstr(&self.name)),
            ("objective", jstr(&objective_to_str(&self.objective))),
            ("seed", jint(self.seed)),
            ("arrival", self.arrival.to_json()),
            ("phases", Json::Arr(self.phases.iter().map(|p| p.to_json()).collect())),
            ("slo", slo_to_json(&self.slo)),
        ])
    }

    fn from_json(j: &Json, at: &KeyPath) -> Result<StreamCfg> {
        let m = obj(j, at)?;
        check_keys(m, &["arrival", "name", "objective", "phases", "seed", "slo"], at)?;
        let name = str_field(m, "name", at)?.to_string();
        let mut phases = Vec::new();
        for (i, p) in arr_field(m, "phases", at)?.iter().enumerate() {
            phases.push(Phase::from_json(p, &at.key("phases").index(i))?);
        }
        let slo = match m.get("slo") {
            Some(s) => slo_from_json(s, &at.key("slo"))?,
            None => StreamSlo::default(),
        };
        Ok(StreamCfg {
            objective: objective_from_str(str_field(m, "objective", at)?)
                .with_context(|| at.key("objective").to_string())?,
            seed: int_field(m, "seed", at)?,
            arrival: Arrival::from_json(field(m, "arrival", at)?, &at.key("arrival"))?,
            phases,
            slo,
            name,
        })
    }
}

impl SystemCfg {
    /// Lower onto the paper testbed's device configs with this pool's
    /// inventory and interconnect.
    pub fn build(&self) -> SystemSpec {
        let base = SystemSpec::paper_testbed(self.interconnect);
        SystemSpec { n_fpga: self.n_fpga, n_gpu: self.n_gpu, ..base }
    }

    fn to_json(&self) -> Json {
        obj_from(vec![
            ("interconnect", jstr(interconnect_to_str(self.interconnect))),
            ("n_fpga", jint(self.n_fpga as u64)),
            ("n_gpu", jint(self.n_gpu as u64)),
        ])
    }

    fn from_json(j: &Json, at: &KeyPath) -> Result<SystemCfg> {
        let m = obj(j, at)?;
        check_keys(m, &["interconnect", "n_fpga", "n_gpu"], at)?;
        let cfg = SystemCfg {
            n_fpga: int_field(m, "n_fpga", at)? as usize,
            n_gpu: int_field(m, "n_gpu", at)? as usize,
            interconnect: Interconnect::parse(str_field(m, "interconnect", at)?)
                .with_context(|| at.key("interconnect").to_string())?,
        };
        if cfg.n_fpga + cfg.n_gpu == 0 {
            bail!("{at}: the device pool is empty");
        }
        Ok(cfg)
    }
}

impl BudgetCfg {
    pub fn build(&self) -> EnergyBudget {
        EnergyBudget::from_power_cap(self.cap_watts, self.window)
    }

    fn to_json(&self) -> Json {
        obj_from(vec![("cap_watts", jnum(self.cap_watts)), ("window", jnum(self.window))])
    }

    fn from_json(j: &Json, at: &KeyPath) -> Result<BudgetCfg> {
        let m = obj(j, at)?;
        check_keys(m, &["cap_watts", "window"], at)?;
        let cfg = BudgetCfg {
            cap_watts: num_field(m, "cap_watts", at)?,
            window: num_field(m, "window", at)?,
        };
        if cfg.cap_watts <= 0.0 || !cfg.cap_watts.is_finite() {
            bail!("{}: must be positive and finite", at.key("cap_watts"));
        }
        if cfg.window <= 0.0 || !cfg.window.is_finite() {
            bail!("{}: must be positive and finite", at.key("window"));
        }
        Ok(cfg)
    }
}

/// A manifest lowered to engine vocabulary, ready for
/// [`crate::experiments::run_multi_stream_with`].
#[derive(Debug, Clone)]
pub struct BuiltScenario {
    pub system: SystemSpec,
    pub streams: Vec<StreamSpec>,
    pub budget: Option<EnergyBudget>,
    pub perturbations: Vec<Perturbation>,
    /// Manifest-level trace opt-in, passed through for runners to attach
    /// a recorder (the scenario cannot carry the recorder itself — it is
    /// per-run state, not configuration).
    pub telemetry: bool,
}

impl BuiltScenario {
    /// Fold the scenario's budget and perturbation script into an engine
    /// config. The policy under test supplies the rest (repartitioning,
    /// SLO controller); the scenario supplies the environment.
    pub fn apply(&self, mut cfg: EngineConfig) -> EngineConfig {
        if let Some(b) = &self.budget {
            cfg.energy_budget = Some(b.clone());
        }
        cfg.perturbations = self.perturbations.clone();
        cfg
    }
}

impl ScenarioManifest {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("description", jstr(&self.description)),
            ("name", jstr(&self.name)),
            ("streams", Json::Arr(self.streams.iter().map(|s| s.to_json()).collect())),
            ("system", self.system.to_json()),
        ];
        if let Some(b) = &self.budget {
            pairs.push(("budget", b.to_json()));
        }
        if !self.perturbations.is_empty() {
            let ps = self.perturbations.iter().map(perturbation_to_json).collect();
            pairs.push(("perturbations", Json::Arr(ps)));
        }
        if self.telemetry {
            pairs.push(("telemetry", Json::Bool(true)));
        }
        obj_from(pairs)
    }

    pub fn from_json(j: &Json) -> Result<ScenarioManifest> {
        let at = KeyPath::root("manifest");
        let m = obj(j, &at)?;
        let keys =
            ["budget", "description", "name", "perturbations", "streams", "system", "telemetry"];
        check_keys(m, &keys, &at)?;
        let name = str_field(m, "name", &at)?.to_string();
        Self::from_obj(m, &at).with_context(|| format!("scenario '{name}'"))
    }

    fn from_obj(m: &BTreeMap<String, Json>, at: &KeyPath) -> Result<ScenarioManifest> {
        let name = str_field(m, "name", at)?.to_string();
        let description = str_field(m, "description", at)?.to_string();
        let system = SystemCfg::from_json(field(m, "system", at)?, &at.key("system"))?;
        let mut streams = Vec::new();
        for (i, s) in arr_field(m, "streams", at)?.iter().enumerate() {
            streams.push(StreamCfg::from_json(s, &at.key("streams").index(i))?);
        }
        if streams.is_empty() {
            bail!("{}: needs at least one stream", at.key("streams"));
        }
        let budget = match m.get("budget") {
            Some(b) => Some(BudgetCfg::from_json(b, &at.key("budget"))?),
            None => None,
        };
        let mut perturbations = Vec::new();
        if m.contains_key("perturbations") {
            for (i, p) in arr_field(m, "perturbations", at)?.iter().enumerate() {
                perturbations.push(perturbation_from_json(p, &at.key("perturbations").index(i))?);
            }
        }
        let telemetry = match m.get("telemetry") {
            Some(v) => {
                v.as_bool().with_context(|| format!("{}: must be a bool", at.key("telemetry")))?
            }
            None => false,
        };
        Ok(ScenarioManifest {
            name,
            description,
            system,
            streams,
            budget,
            perturbations,
            telemetry,
        })
    }

    pub fn parse_str(text: &str) -> Result<ScenarioManifest> {
        let j = json::parse(text).context("manifest is not valid JSON")?;
        ScenarioManifest::from_json(&j)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<ScenarioManifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        ScenarioManifest::parse_str(&text).with_context(|| format!("in {}", path.display()))
    }

    /// The checked-in file name for this manifest under `scenarios/`.
    pub fn file_name(&self) -> String {
        format!("{}.json", self.name.replace('-', "_"))
    }

    /// Lower to engine vocabulary. Value validation (arrival rates,
    /// perturbation scripts) panics eagerly, mirroring the engine's own
    /// stance; structural errors come back as `Err`.
    pub fn build(&self) -> Result<BuiltScenario> {
        let mut streams = Vec::new();
        for s in &self.streams {
            streams.push(s.build()?);
        }
        for p in &self.perturbations {
            p.validate(streams.len());
        }
        Ok(BuiltScenario {
            system: self.system.build(),
            streams,
            budget: self.budget.as_ref().map(BudgetCfg::build),
            perturbations: self.perturbations.clone(),
            telemetry: self.telemetry,
        })
    }

    /// Indented serialization for the checked-in `scenarios/*.json`
    /// files — same tree as [`Self::to_json`], human-diffable layout.
    pub fn to_pretty_string(&self) -> String {
        let mut out = String::new();
        pretty(&self.to_json(), 0, &mut out);
        out.push('\n');
        out
    }
}

// ---------------------------------------------------------------------
// Scalar codecs. `Objective::parse` and `Interconnect::parse` are lossy
// / many-to-one on purpose (CLI ergonomics); the manifest codec pins one
// canonical spelling per value so serialize∘parse is the identity.

fn objective_to_str(o: &Objective) -> String {
    match o {
        Objective::Performance => "perf".to_string(),
        Objective::Energy => "energy".to_string(),
        Objective::Balanced { min_throughput_frac } => format!("balanced:{min_throughput_frac}"),
        Objective::QoS { min_throughput } => format!("qos:{min_throughput}"),
    }
}

fn objective_from_str(s: &str) -> Result<Objective> {
    if let Some(frac) = s.strip_prefix("balanced:") {
        let msg = || format!("bad balanced fraction in '{s}'");
        return Ok(Objective::Balanced { min_throughput_frac: frac.parse().with_context(msg)? });
    }
    Objective::parse(s)
}

fn migration_to_str(m: &MigrationMode) -> String {
    match m {
        MigrationMode::Drain => "drain".to_string(),
        MigrationMode::Preempt { min_remaining } => format!("preempt:{min_remaining}"),
    }
}

fn migration_from_str(s: &str) -> Result<MigrationMode> {
    if s == "drain" {
        return Ok(MigrationMode::Drain);
    }
    match s.strip_prefix("preempt:") {
        Some(t) => {
            let msg = || format!("bad preempt threshold in '{s}'");
            Ok(MigrationMode::Preempt { min_remaining: t.parse().with_context(msg)? })
        }
        None => bail!("unknown migration mode '{s}' (drain|preempt:<min_remaining>)"),
    }
}

fn interconnect_to_str(ic: Interconnect) -> &'static str {
    match ic {
        Interconnect::Pcie4 => "pcie4",
        Interconnect::Pcie5 => "pcie5",
        Interconnect::Cxl3 => "cxl3",
    }
}

fn slo_to_json(slo: &StreamSlo) -> Json {
    let mut pairs = vec![("priority", jnum(slo.priority))];
    if let Some(t) = slo.p99_target {
        pairs.push(("p99_target", jnum(t)));
    }
    if let Some(d) = slo.deadline {
        pairs.push(("deadline", jnum(d)));
    }
    if let Some(m) = slo.migration {
        pairs.push(("migration", jstr(&migration_to_str(&m))));
    }
    obj_from(pairs)
}

fn slo_from_json(j: &Json, at: &KeyPath) -> Result<StreamSlo> {
    let m = obj(j, at)?;
    check_keys(m, &["deadline", "migration", "p99_target", "priority"], at)?;
    let mut slo = StreamSlo::default();
    if let Some(p) = opt_num(m, "priority", at)? {
        slo.priority = p;
    }
    slo.p99_target = opt_num(m, "p99_target", at)?;
    slo.deadline = opt_num(m, "deadline", at)?;
    if let Some(v) = m.get("migration") {
        let msg = || format!("{}: must be a string", at.key("migration"));
        slo.migration = Some(
            migration_from_str(v.as_str().with_context(msg)?)
                .with_context(|| at.key("migration").to_string())?,
        );
    }
    slo.validate();
    Ok(slo)
}

fn perturbation_to_json(p: &Perturbation) -> Json {
    let mut pairs = vec![("at", jnum(p.at))];
    match &p.kind {
        PerturbationKind::DeviceCut { n_fpga, n_gpu } => {
            pairs.push(("kind", jstr("device-cut")));
            pairs.push(("n_fpga", jint(*n_fpga as u64)));
            pairs.push(("n_gpu", jint(*n_gpu as u64)));
        }
        PerturbationKind::BudgetScale { factor } => {
            pairs.push(("kind", jstr("budget-scale")));
            pairs.push(("factor", jnum(*factor)));
        }
        PerturbationKind::SloTighten { stream, p99_scale, deadline_scale } => {
            pairs.push(("kind", jstr("slo-tighten")));
            pairs.push(("stream", jint(*stream as u64)));
            pairs.push(("p99_scale", jnum(*p99_scale)));
            pairs.push(("deadline_scale", jnum(*deadline_scale)));
        }
    }
    obj_from(pairs)
}

fn perturbation_from_json(j: &Json, at: &KeyPath) -> Result<Perturbation> {
    let m = obj(j, at)?;
    let when = num_field(m, "at", at)?;
    let kind = str_field(m, "kind", at)?;
    Ok(match kind {
        "device-cut" => {
            check_keys(m, &["at", "kind", "n_fpga", "n_gpu"], at)?;
            let n_fpga = int_field(m, "n_fpga", at)? as usize;
            let n_gpu = int_field(m, "n_gpu", at)? as usize;
            Perturbation::device_cut(when, n_fpga, n_gpu)
        }
        "budget-scale" => {
            check_keys(m, &["at", "factor", "kind"], at)?;
            Perturbation::budget_scale(when, num_field(m, "factor", at)?)
        }
        "slo-tighten" => {
            check_keys(m, &["at", "deadline_scale", "kind", "p99_scale", "stream"], at)?;
            Perturbation::slo_tighten(
                when,
                int_field(m, "stream", at)? as usize,
                num_field(m, "p99_scale", at)?,
                num_field(m, "deadline_scale", at)?,
            )
        }
        other => bail!("{}: unknown perturbation kind '{other}'", at.key("kind")),
    })
}

// ---------------------------------------------------------------------
// JSON plumbing: tiny constructors, strict-object accessors, pretty
// printer.

fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

fn jnum(x: f64) -> Json {
    Json::Num(x)
}

fn jint(x: u64) -> Json {
    Json::Num(x as f64)
}

fn obj_from(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn obj<'a>(j: &'a Json, at: &KeyPath) -> Result<&'a BTreeMap<String, Json>> {
    j.as_obj().with_context(|| format!("{at}: expected an object"))
}

/// The strictness gate: every object's keys must be a subset of what the
/// schema names, so a misspelled manifest key is an error, not a silent
/// default.
fn check_keys(m: &BTreeMap<String, Json>, allowed: &[&str], at: &KeyPath) -> Result<()> {
    for key in m.keys() {
        if !allowed.contains(&key.as_str()) {
            bail!("{at}: unknown key '{key}' (expected one of: {})", allowed.join(", "));
        }
    }
    Ok(())
}

fn field<'a>(m: &'a BTreeMap<String, Json>, key: &str, at: &KeyPath) -> Result<&'a Json> {
    m.get(key).with_context(|| format!("{at}: missing field '{key}'"))
}

fn num_field(m: &BTreeMap<String, Json>, key: &str, at: &KeyPath) -> Result<f64> {
    let v = field(m, key, at)?;
    v.as_f64().with_context(|| format!("{}: must be a number", at.key(key)))
}

fn int_field(m: &BTreeMap<String, Json>, key: &str, at: &KeyPath) -> Result<u64> {
    let v = field(m, key, at)?;
    v.as_u64().with_context(|| format!("{}: must be a non-negative integer", at.key(key)))
}

fn str_field<'a>(m: &'a BTreeMap<String, Json>, key: &str, at: &KeyPath) -> Result<&'a str> {
    let v = field(m, key, at)?;
    v.as_str().with_context(|| format!("{}: must be a string", at.key(key)))
}

fn arr_field<'a>(m: &'a BTreeMap<String, Json>, key: &str, at: &KeyPath) -> Result<&'a [Json]> {
    let v = field(m, key, at)?;
    v.as_arr().with_context(|| format!("{}: must be an array", at.key(key)))
}

fn opt_num(m: &BTreeMap<String, Json>, key: &str, at: &KeyPath) -> Result<Option<f64>> {
    match m.get(key) {
        None => Ok(None),
        Some(v) => {
            let msg = || format!("{}: must be a number", at.key(key));
            Ok(Some(v.as_f64().with_context(msg)?))
        }
    }
}

fn pretty(j: &Json, depth: usize, out: &mut String) {
    match j {
        Json::Arr(v) if !v.is_empty() => {
            out.push_str("[\n");
            for (i, x) in v.iter().enumerate() {
                indent(out, depth + 1);
                pretty(x, depth + 1, out);
                if i + 1 < v.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            indent(out, depth);
            out.push(']');
        }
        Json::Obj(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, x)) in m.iter().enumerate() {
                indent(out, depth + 1);
                out.push_str(&Json::Str(k.clone()).to_string());
                out.push_str(": ");
                pretty(x, depth + 1, out);
                if i + 1 < m.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            indent(out, depth);
            out.push('}');
        }
        leaf => out.push_str(&leaf.to_string()),
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::generate_trace;

    fn kitchen_sink() -> ScenarioManifest {
        ScenarioManifest {
            name: "kitchen-sink".to_string(),
            description: "every schema feature at once".to_string(),
            system: SystemCfg { n_fpga: 2, n_gpu: 1, interconnect: Interconnect::Cxl3 },
            streams: vec![
                StreamCfg {
                    name: "gnn-lane".to_string(),
                    objective: Objective::Performance,
                    seed: 7,
                    arrival: Arrival::FlashCrowd {
                        base_rate: 5.0,
                        peak_rate: 80.0,
                        start: 0.5,
                        duration: 0.25,
                    },
                    phases: vec![
                        Phase {
                            workload: WorkloadCfg::Gcn {
                                code: "TF".to_string(),
                                graph: "traffic".to_string(),
                                vertices: 1_000_000,
                                edges: 2_000_000,
                                feature_len: 200,
                                degree_skew: 0.2,
                                layers: 2,
                                hidden: 128,
                            },
                            count: 3,
                        },
                        Phase {
                            workload: WorkloadCfg::Gin {
                                code: "PR".to_string(),
                                graph: "products".to_string(),
                                vertices: 400_000,
                                edges: 1_200_000,
                                feature_len: 100,
                                degree_skew: 0.6,
                                layers: 3,
                                hidden: 64,
                                mlp_layers: 2,
                            },
                            count: 2,
                        },
                    ],
                    slo: StreamSlo::target(0.1, 3.0)
                        .with_deadline(0.25)
                        .with_migration(MigrationMode::Preempt { min_remaining: 0.005 }),
                },
                StreamCfg {
                    name: "txf-lane".to_string(),
                    objective: Objective::Balanced { min_throughput_frac: 0.7 },
                    seed: 8,
                    arrival: Arrival::Mmpp { rates: vec![4.0, 40.0], dwell: 0.5 },
                    phases: vec![Phase {
                        workload: WorkloadCfg::Transformer { seq: 2048, window: 512, layers: 4 },
                        count: 4,
                    }],
                    slo: StreamSlo::best_effort(1.0),
                },
            ],
            budget: Some(BudgetCfg { cap_watts: 200.0, window: 0.25 }),
            perturbations: vec![
                Perturbation::device_cut(0.4, 1, 0),
                Perturbation::budget_scale(0.6, 0.5),
                Perturbation::slo_tighten(0.8, 0, 0.5, 1.0),
            ],
            telemetry: true,
        }
    }

    #[test]
    fn kitchen_sink_round_trips_compact_and_pretty() {
        let m = kitchen_sink();
        let compact = ScenarioManifest::parse_str(&m.to_json().to_string()).unwrap();
        assert_eq!(compact, m);
        let pretty = ScenarioManifest::parse_str(&m.to_pretty_string()).unwrap();
        assert_eq!(pretty, m);
        assert_eq!(m.file_name(), "kitchen_sink.json");
    }

    #[test]
    fn kitchen_sink_builds() {
        let built = kitchen_sink().build().unwrap();
        assert_eq!(built.system.n_fpga, 2);
        assert_eq!(built.system.n_gpu, 1);
        assert!(built.telemetry, "the manifest opt-in survives the build");
        assert_eq!(built.streams.len(), 2);
        assert_eq!(built.streams[0].trace.len(), 5);
        assert_eq!(built.streams[0].slo.deadline, Some(0.25));
        assert!(built.budget.is_some());
        assert_eq!(built.perturbations.len(), 3);
        // Ids are trace positions; arrivals are non-decreasing.
        for (i, r) in built.streams[0].trace.iter().enumerate() {
            assert_eq!(r.id, i);
            if i > 0 {
                assert!(r.arrival >= built.streams[0].trace[i - 1].arrival);
            }
        }
        // The engine config inherits budget + perturbation script.
        let cfg = built.apply(EngineConfig::default());
        assert!(cfg.energy_budget.is_some());
        assert_eq!(cfg.perturbations.len(), 3);
    }

    #[test]
    fn poisson_times_match_generate_trace_bit_for_bit() {
        let ds = Dataset::new("TF", "traffic", 1_000_000, 2_000_000, 200, 0.2);
        let wl = gnn::gcn_workload(&ds, 2, 128);
        let legacy = generate_trace(&[(wl, 12)], 40.0, 9);
        let times = Arrival::Poisson { rate: 40.0 }.times(12, 9);
        assert_eq!(times.len(), 12);
        for (r, t) in legacy.iter().zip(&times) {
            assert_eq!(r.arrival.to_bits(), t.to_bits(), "divergence at id {}", r.id);
        }
    }

    #[test]
    fn unknown_keys_are_rejected_everywhere() {
        let m = kitchen_sink();
        let Json::Obj(mut top) = m.to_json() else { panic!("manifest serializes to an object") };
        top.insert("typo".to_string(), Json::Bool(true));
        let err = ScenarioManifest::from_json(&Json::Obj(top)).unwrap_err();
        assert!(format!("{err:#}").contains("unknown key 'typo'"), "{err:#}");

        let bad_stream = r#"{"description": "d", "name": "x", "system":
            {"interconnect": "pcie4", "n_fpga": 1, "n_gpu": 1}, "streams": [
            {"name": "s", "objective": "perf", "seed": 1,
             "arrival": {"kind": "poisson", "rate": 2.0, "surprise": 1},
             "phases": [{"count": 1, "workload":
                {"kind": "transformer", "seq": 128, "window": 64, "layers": 1}}]}]}"#;
        let err = ScenarioManifest::parse_str(bad_stream).unwrap_err();
        assert!(format!("{err:#}").contains("unknown key 'surprise'"), "{err:#}");
    }

    #[test]
    fn missing_fields_name_the_field_and_context() {
        let text = r#"{"description": "d", "name": "x", "streams": [],
            "system": {"interconnect": "pcie4", "n_fpga": 1}}"#;
        let err = ScenarioManifest::parse_str(text).unwrap_err();
        assert!(format!("{err:#}").contains("missing field 'n_gpu'"), "{err:#}");
        assert!(format!("{err:#}").contains("scenario 'x'"), "{err:#}");
    }

    #[test]
    fn codec_errors_carry_full_key_paths() {
        let bad_deadline = r#"{"description": "d", "name": "x", "system":
            {"interconnect": "pcie4", "n_fpga": 1, "n_gpu": 1}, "streams": [
            {"name": "s", "objective": "perf", "seed": 1,
             "arrival": {"kind": "poisson", "rate": 2.0},
             "phases": [{"count": 1, "workload":
                {"kind": "transformer", "seq": 128, "window": 64, "layers": 1}}],
             "slo": {"deadline": "soon"}}]}"#;
        let err = ScenarioManifest::parse_str(bad_deadline).unwrap_err();
        assert!(format!("{err:#}").contains("streams[0].slo.deadline"), "{err:#}");

        let bad_rate = r#"{"description": "d", "name": "x", "system":
            {"interconnect": "pcie4", "n_fpga": 1, "n_gpu": 1}, "streams": [
            {"name": "s", "objective": "perf", "seed": 1,
             "arrival": {"kind": "mmpp", "rates": [4.0, "fast"], "dwell": 0.5},
             "phases": [{"count": 1, "workload":
                {"kind": "transformer", "seq": 128, "window": 64, "layers": 1}}]}]}"#;
        let err = ScenarioManifest::parse_str(bad_rate).unwrap_err();
        assert!(format!("{err:#}").contains("streams[0].arrival.rates[1]"), "{err:#}");

        let bad_workload = r#"{"description": "d", "name": "x", "system":
            {"interconnect": "pcie4", "n_fpga": 1, "n_gpu": 1}, "streams": [
            {"name": "s", "objective": "perf", "seed": 1,
             "arrival": {"kind": "poisson", "rate": 2.0},
             "phases": [{"count": 1, "workload":
                {"kind": "transformer", "seq": 128, "window": 64}}]}]}"#;
        let err = ScenarioManifest::parse_str(bad_workload).unwrap_err();
        assert!(format!("{err:#}").contains("streams[0].phases[0].workload"), "{err:#}");
        assert!(format!("{err:#}").contains("missing field 'layers'"), "{err:#}");
    }

    #[test]
    fn scalar_codecs_pin_one_spelling_per_value() {
        for o in [
            Objective::Performance,
            Objective::Energy,
            Objective::Balanced { min_throughput_frac: 0.7 },
            Objective::QoS { min_throughput: 12.5 },
        ] {
            assert_eq!(objective_from_str(&objective_to_str(&o)).unwrap(), o);
        }
        for m in [MigrationMode::Drain, MigrationMode::Preempt { min_remaining: 0.005 }] {
            assert_eq!(migration_from_str(&migration_to_str(&m)).unwrap(), m);
        }
        for ic in [Interconnect::Pcie4, Interconnect::Pcie5, Interconnect::Cxl3] {
            assert_eq!(Interconnect::parse(interconnect_to_str(ic)).unwrap(), ic);
        }
        assert!(migration_from_str("teleport").is_err());
        assert!(objective_from_str("balanced:x").is_err());
    }

    #[test]
    fn arrival_curves_hit_their_landmarks() {
        let d = Arrival::Diurnal { base_rate: 10.0, peak_rate: 50.0, period: 8.0 };
        assert!((d.rate_at(0.0) - 10.0).abs() < 1e-9);
        assert!((d.rate_at(4.0) - 50.0).abs() < 1e-9, "peak at half period");
        assert!((d.rate_at(8.0) - 10.0).abs() < 1e-9, "periodic");

        let f = Arrival::FlashCrowd { base_rate: 5.0, peak_rate: 200.0, start: 1.0, duration: 0.5 };
        assert_eq!(f.rate_at(0.9), 5.0);
        assert_eq!(f.rate_at(1.0), 200.0);
        assert_eq!(f.rate_at(1.49), 200.0);
        assert_eq!(f.rate_at(1.5), 5.0);

        let m = Arrival::Mmpp { rates: vec![2.0, 20.0, 8.0], dwell: 0.5 }; // cycles
        assert_eq!(m.rate_at(0.1), 2.0);
        assert_eq!(m.rate_at(0.6), 20.0);
        assert_eq!(m.rate_at(1.2), 8.0);
        assert_eq!(m.rate_at(1.6), 2.0);
    }

    #[test]
    #[should_panic(expected = "must be positive and finite")]
    fn zero_rate_arrivals_fail_validation() {
        Arrival::Poisson { rate: 0.0 }.times(3, 1);
    }

    #[test]
    fn burst_arrivals_cluster_inside_the_burst() {
        // At base 2/s vs peak 400/s over [0.2, 0.7), most of a 60-request
        // trace must land inside the burst window.
        let a = Arrival::FlashCrowd { base_rate: 2.0, peak_rate: 400.0, start: 0.2, duration: 0.5 };
        let times = a.times(60, 3);
        let inside = times.iter().filter(|t| (0.2..0.7).contains(*t)).count();
        assert!(inside > 40, "only {inside} of 60 arrivals inside the burst");
    }
}
