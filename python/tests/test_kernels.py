"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Parametrized sweeps + hypothesis-driven shape/seed exploration.  These are
the build-time correctness gate: `make test` runs them before anything is
lowered to artifacts.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import formats
from compile.kernels.gemm import gemm
from compile.kernels.spmm import spmm
from compile.kernels.window_attn import window_attention
from compile.kernels.ref import (
    gemm_ref,
    spmm_ref,
    window_attention_ref,
    layernorm_ref,
)

RTOL, ATOL = 1e-4, 1e-3


# ---------------------------------------------------------------- GEMM ----
@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (128, 128, 128, 128, 128, 128),
        (256, 128, 384, 128, 128, 64),
        (512, 256, 128, 128, 128, 128),
        (128, 512, 256, 64, 128, 128),
        (64, 64, 64, 64, 64, 64),
    ],
)
def test_gemm_matches_ref(m, k, n, bm, bn, bk):
    rng = np.random.default_rng(m + k + n)
    a = rng.standard_normal((m, k), dtype=np.float32)
    b = rng.standard_normal((k, n), dtype=np.float32)
    assert_allclose(gemm(a, b, bm=bm, bn=bn, bk=bk), gemm_ref(a, b), rtol=RTOL, atol=ATOL)


def test_gemm_identity():
    eye = np.eye(128, dtype=np.float32)
    x = np.random.default_rng(0).standard_normal((128, 128), dtype=np.float32)
    assert_allclose(gemm(x, eye), x, rtol=RTOL, atol=ATOL)


def test_gemm_zeros():
    z = np.zeros((128, 128), dtype=np.float32)
    x = np.ones((128, 128), dtype=np.float32)
    assert_allclose(gemm(x, z), z, rtol=0, atol=0)


def test_gemm_rejects_misaligned():
    # 100 rows with an explicit 64-row block: not divisible. (Blocks are
    # auto-clamped to the problem size, so only explicit blocks that do
    # not divide the dims can fail.)
    a = np.zeros((100, 128), dtype=np.float32)
    b = np.zeros((128, 128), dtype=np.float32)
    with pytest.raises(AssertionError):
        gemm(a, b, bm=64)


@settings(max_examples=15, deadline=None)
@given(
    mi=st.integers(1, 4),
    ki=st.integers(1, 4),
    ni=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_hypothesis(mi, ki, ni, seed):
    m, k, n = 64 * mi, 64 * ki, 64 * ni
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k), dtype=np.float32)
    b = rng.standard_normal((k, n), dtype=np.float32)
    assert_allclose(gemm(a, b, bm=64, bn=64, bk=64), gemm_ref(a, b), rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------- SpMM ----
@pytest.mark.parametrize(
    "m,k,n,tm,tk,ell,fill",
    [
        (256, 512, 96, 64, 64, 3, 0.7),
        (128, 128, 128, 128, 128, 1, 1.0),
        (512, 256, 64, 64, 128, 2, 0.5),
        (256, 1024, 128, 128, 128, 8, 1.0),
        (64, 64, 32, 64, 64, 1, 1.0),
    ],
)
def test_spmm_matches_dense(m, k, n, tm, tk, ell, fill):
    ell_mat = formats.random_block_ell(m, k, tm=tm, tk=tk, ell_width=ell, fill=fill, seed=m + k)
    x = np.random.default_rng(n).standard_normal((k, n), dtype=np.float32)
    out = spmm(jnp.asarray(ell_mat.blocks), jnp.asarray(ell_mat.indices), jnp.asarray(x))
    assert_allclose(out, ell_mat.to_dense() @ x, rtol=RTOL, atol=ATOL)


def test_spmm_matches_ref_oracle():
    ell_mat = formats.random_block_ell(256, 512, tm=64, tk=64, ell_width=3, fill=0.7, seed=1)
    x = np.random.default_rng(2).standard_normal((512, 96), dtype=np.float32)
    b, i, xj = jnp.asarray(ell_mat.blocks), jnp.asarray(ell_mat.indices), jnp.asarray(x)
    assert_allclose(spmm(b, i, xj), spmm_ref(b, i, xj, 512), rtol=RTOL, atol=ATOL)


def test_spmm_all_padding_is_zero():
    """A matrix of only padding slots multiplies to exactly zero."""
    blocks = np.zeros((4, 2, 64, 64), dtype=np.float32)
    indices = np.zeros((4, 2), dtype=np.int32)
    x = np.ones((128, 32), dtype=np.float32)
    out = spmm(jnp.asarray(blocks), jnp.asarray(indices), jnp.asarray(x))
    assert np.all(np.asarray(out) == 0.0)


def test_spmm_duplicate_indices_accumulate():
    """Two slots pointing at the same K-block must both contribute."""
    blocks = np.ones((1, 2, 64, 64), dtype=np.float32)
    indices = np.zeros((1, 2), dtype=np.int32)  # both slots -> K-block 0
    x = np.ones((64, 16), dtype=np.float32)
    out = np.asarray(spmm(jnp.asarray(blocks), jnp.asarray(indices), jnp.asarray(x)))
    assert_allclose(out, np.full((64, 16), 2 * 64.0), rtol=0, atol=0)


def test_dense_to_block_ell_roundtrip():
    rng = np.random.default_rng(7)
    a = rng.standard_normal((128, 256), dtype=np.float32)
    a[a < 1.0] = 0.0  # sparsify
    ell_mat = formats.dense_to_block_ell(a, tm=64, tk=64)
    assert_allclose(ell_mat.to_dense(), a, rtol=0, atol=0)


def test_dense_to_block_ell_rejects_overflow():
    a = np.ones((64, 256), dtype=np.float32)  # 4 non-empty K-blocks
    with pytest.raises(ValueError):
        formats.dense_to_block_ell(a, tm=64, tk=64, ell_width=2)


@settings(max_examples=12, deadline=None)
@given(
    nrt=st.integers(1, 4),
    nkb=st.integers(2, 6),
    ell=st.integers(1, 4),
    n=st.sampled_from([32, 64, 128]),
    fill=st.floats(0.3, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_hypothesis(nrt, nkb, ell, n, fill, seed):
    ell = min(ell, nkb)
    tm = tk = 64
    m, k = nrt * tm, nkb * tk
    ell_mat = formats.random_block_ell(m, k, tm=tm, tk=tk, ell_width=ell, fill=fill, seed=seed)
    x = np.random.default_rng(seed + 1).standard_normal((k, n), dtype=np.float32)
    out = spmm(jnp.asarray(ell_mat.blocks), jnp.asarray(ell_mat.indices), jnp.asarray(x))
    assert_allclose(out, ell_mat.to_dense() @ x, rtol=RTOL, atol=ATOL)


# -------------------------------------------------- window attention ----
@pytest.mark.parametrize(
    "h,s,d,w,bq",
    [
        (2, 256, 64, 128, 64),
        (1, 128, 32, 64, 32),
        (4, 512, 64, 128, 128),
        (2, 256, 64, 256, 64),   # window == seq: full attention
        (1, 192, 64, 64, 64),
    ],
)
def test_window_attention_matches_ref(h, s, d, w, bq):
    rng = np.random.default_rng(h * s + w)
    q = rng.standard_normal((h, s, d), dtype=np.float32) * 0.3
    k = rng.standard_normal((h, s, d), dtype=np.float32) * 0.3
    v = rng.standard_normal((h, s, d), dtype=np.float32)
    out = window_attention(q, k, v, window=w, bq=bq)
    assert_allclose(out, window_attention_ref(q, k, v, w), rtol=RTOL, atol=ATOL)


def test_window_attention_full_window_equals_softmax_attn():
    """window >= seq reduces to vanilla attention."""
    rng = np.random.default_rng(3)
    h, s, d = 1, 128, 32
    q = rng.standard_normal((h, s, d), dtype=np.float32) * 0.2
    k = rng.standard_normal((h, s, d), dtype=np.float32) * 0.2
    v = rng.standard_normal((h, s, d), dtype=np.float32)
    out = window_attention(q, k, v, window=256, bq=64)
    scores = np.einsum("hqd,hkd->hqk", q, k) / np.sqrt(d)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    assert_allclose(out, np.einsum("hqk,hkd->hqd", p, v), rtol=RTOL, atol=ATOL)


def test_window_attention_rows_are_convex_combinations():
    """With constant V the output must be exactly V (softmax sums to 1)."""
    h, s, d, w = 1, 128, 32, 64
    rng = np.random.default_rng(5)
    q = rng.standard_normal((h, s, d), dtype=np.float32)
    k = rng.standard_normal((h, s, d), dtype=np.float32)
    v = np.full((h, s, d), 3.25, dtype=np.float32)
    out = window_attention(q, k, v, window=w, bq=64)
    assert_allclose(out, v, rtol=1e-5, atol=1e-4)


def test_window_attention_rejects_bad_alignment():
    q = np.zeros((1, 100, 32), dtype=np.float32)
    with pytest.raises(AssertionError):
        window_attention(q, q, q, window=64, bq=32)


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(1, 3),
    sblk=st.integers(2, 6),
    d=st.sampled_from([32, 64]),
    wblk=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_window_attention_hypothesis(h, sblk, d, wblk, seed):
    bq = 64
    s, w = sblk * bq, wblk * bq
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((h, s, d), dtype=np.float32) * 0.3
    k = rng.standard_normal((h, s, d), dtype=np.float32) * 0.3
    v = rng.standard_normal((h, s, d), dtype=np.float32)
    out = window_attention(q, k, v, window=w, bq=bq)
    assert_allclose(out, window_attention_ref(q, k, v, w), rtol=RTOL, atol=ATOL)
