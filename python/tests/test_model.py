"""L2 model correctness: layer compositions vs pure-jnp references."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import formats
from compile.kernels.ref import window_attention_ref, layernorm_ref

RTOL, ATOL = 1e-4, 1e-3


def _graph(v=256, f=64, tm=64, tk=64, ell=2, seed=0):
    ell_mat = formats.random_block_ell(v, v, tm=tm, tk=tk, ell_width=ell, fill=1.0, seed=seed)
    rng = np.random.default_rng(seed + 1)
    x = rng.standard_normal((v, f), dtype=np.float32) * 0.5
    return ell_mat, x, rng


def test_gcn_layer_matches_dense_ref():
    ell_mat, x, rng = _graph()
    theta = rng.standard_normal((64, 64), dtype=np.float32) * 0.2
    out = model.gcn_layer(
        jnp.asarray(ell_mat.blocks), jnp.asarray(ell_mat.indices), jnp.asarray(x), jnp.asarray(theta)
    )
    ref = np.maximum(ell_mat.to_dense() @ x @ theta, 0.0)
    assert_allclose(out, ref, rtol=RTOL, atol=ATOL)


def test_gin_layer_matches_dense_ref():
    ell_mat, x, rng = _graph(seed=3)
    w1 = rng.standard_normal((64, 64), dtype=np.float32) * 0.2
    b1 = rng.standard_normal((64,), dtype=np.float32) * 0.1
    w2 = rng.standard_normal((64, 64), dtype=np.float32) * 0.2
    b2 = rng.standard_normal((64,), dtype=np.float32) * 0.1
    out = model.gin_layer(
        jnp.asarray(ell_mat.blocks), jnp.asarray(ell_mat.indices), jnp.asarray(x),
        jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2), jnp.asarray(b2),
    )
    y = ell_mat.to_dense() @ x
    ref = np.maximum(y @ w1 + b1, 0.0) @ w2 + b2
    assert_allclose(out, ref, rtol=RTOL, atol=ATOL)


def test_gin_mlp_matches_gin_layer_tail():
    rng = np.random.default_rng(9)
    y = rng.standard_normal((128, 64), dtype=np.float32)
    w1 = rng.standard_normal((64, 64), dtype=np.float32) * 0.2
    b1 = rng.standard_normal((64,), dtype=np.float32) * 0.1
    w2 = rng.standard_normal((64, 64), dtype=np.float32) * 0.2
    b2 = rng.standard_normal((64,), dtype=np.float32) * 0.1
    out = model.gin_mlp(*(jnp.asarray(t) for t in (y, w1, b1, w2, b2)))
    ref = np.maximum(y @ w1 + b1, 0.0) @ w2 + b2
    assert_allclose(out, ref, rtol=RTOL, atol=ATOL)


def _transformer_ref(x, wq, wk, wv, wo, w1, b1, w2, b2, g1, be1, g2, be2, heads, window):
    seq, dm = x.shape
    dh = dm // heads
    split = lambda t: t.reshape(seq, heads, dh).transpose(1, 0, 2)
    z = window_attention_ref(split(x @ wq), split(x @ wk), split(x @ wv), window)
    z = np.asarray(z).transpose(1, 0, 2).reshape(seq, dm)
    h = layernorm_ref(jnp.asarray(x + z @ wo), jnp.asarray(g1), jnp.asarray(be1))
    h = np.asarray(h)
    ffn = np.maximum(h @ w1 + b1, 0.0) @ w2 + b2
    return np.asarray(layernorm_ref(jnp.asarray(h + ffn), jnp.asarray(g2), jnp.asarray(be2)))


def test_transformer_layer_matches_ref():
    seq, dm, heads, dff, window = 256, 128, 2, 256, 128
    rng = np.random.default_rng(11)
    sc = 0.15
    x = rng.standard_normal((seq, dm), dtype=np.float32)
    wq, wk, wv, wo = (rng.standard_normal((dm, dm), dtype=np.float32) * sc for _ in range(4))
    w1 = rng.standard_normal((dm, dff), dtype=np.float32) * sc
    b1 = rng.standard_normal((dff,), dtype=np.float32) * 0.05
    w2 = rng.standard_normal((dff, dm), dtype=np.float32) * sc
    b2 = rng.standard_normal((dm,), dtype=np.float32) * 0.05
    g1 = np.ones((dm,), dtype=np.float32)
    be1 = np.zeros((dm,), dtype=np.float32)
    g2 = np.ones((dm,), dtype=np.float32)
    be2 = np.zeros((dm,), dtype=np.float32)
    out = model.transformer_layer(
        *(jnp.asarray(t) for t in (x, wq, wk, wv, wo, w1, b1, w2, b2, g1, be1, g2, be2)),
        heads=heads, window=window,
    )
    ref = _transformer_ref(x, wq, wk, wv, wo, w1, b1, w2, b2, g1, be1, g2, be2, heads, window)
    assert_allclose(out, ref, rtol=5e-4, atol=5e-3)


def test_transformer_layer_shape_preserved():
    seq, dm, heads, dff, window = 128, 128, 2, 256, 64
    z = jnp.zeros
    out = model.transformer_layer(
        z((seq, dm)), z((dm, dm)), z((dm, dm)), z((dm, dm)), z((dm, dm)),
        z((dm, dff)), z((dff,)), z((dff, dm)), z((dm,)),
        jnp.ones((dm,)), z((dm,)), jnp.ones((dm,)), z((dm,)),
        heads=heads, window=window,
    )
    assert out.shape == (seq, dm)
    assert bool(jnp.all(jnp.isfinite(out)))
