"""AOT path sanity: artifacts lower to parseable HLO text with an ENTRY."""

import json
import os

import pytest

from compile import aot


@pytest.mark.parametrize("name", list(aot.ARTIFACTS))
def test_artifact_lowers_to_hlo_text(name):
    text, out_shape = aot.lower_artifact(name)
    assert "ENTRY" in text, f"{name}: no ENTRY computation in HLO text"
    assert "HloModule" in text
    assert len(out_shape.shape) >= 1
    # The interchange contract: interpret-mode pallas must lower to plain
    # HLO ops, never a Mosaic custom-call the CPU PJRT client can't run.
    assert "mosaic" not in text.lower(), f"{name}: Mosaic custom-call leaked"


def test_manifest_consistent_with_artifacts(tmp_path):
    import subprocess, sys
    # Use the in-process writer instead of a subprocess: call main via argv.
    argv_backup = sys.argv
    sys.argv = ["aot", "--out", str(tmp_path), "--only", "gemm"]
    try:
        aot.main()
    finally:
        sys.argv = argv_backup
    manifest = json.load(open(tmp_path / "manifest.json"))
    entry = manifest["artifacts"]["gemm"]
    assert (tmp_path / entry["file"]).exists()
    assert entry["inputs"][0]["shape"] == [1024, 128]
    assert entry["output"]["shape"] == [1024, 128]
