"""AOT compile path: lower the L2 graphs to HLO text artifacts.

Interchange is HLO *text*, not a serialized ``HloModuleProto``: jax >= 0.5
emits protos with 64-bit instruction ids that the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is lowered with ``return_tuple=True`` — the Rust runtime
unwraps with ``to_tuple1()``.  A ``manifest.json`` describes each artifact
(inputs, outputs, shapes, dtypes) so the Rust side can build input literals
and validate against what it feeds the executable.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# ---------------------------------------------------------------------------
# Artifact catalog.
#
# Shapes are fixed (one compiled executable per variant, as per the
# architecture: "one compiled executable per model variant").  They are the
# shapes of the end-to-end examples, NOT the simulated-evaluation shapes —
# the evaluation harness scales timing analytically via the device models.
# ---------------------------------------------------------------------------

# Graph for the E2E GNN demo: 1024 vertices, 128-dim features,
# block-ELL with 128x128 tiles and ell_width 4.
V, F, NRT, ELL, TM, TK = 1024, 128, 8, 4, 128, 128
# Transformer for the E2E demo: BigBird-ish but CPU-sized.
SEQ, DM, HEADS, DFF, WIN = 512, 256, 4, 512, 128

f32 = jnp.float32
i32 = jnp.int32


def _s(shape, dtype=f32):
    return jax.ShapeDtypeStruct(shape, dtype)


GRAPH_ARGS = [
    ("blocks", _s((NRT, ELL, TM, TK))),
    ("indices", _s((NRT, ELL), i32)),
]


def spmm_kernel(blocks, indices, x):
    from compile.kernels.spmm import spmm

    return spmm(blocks, indices, x)


def gemm_kernel(a, b):
    from compile.kernels.gemm import gemm

    return gemm(a, b)


def wattn_kernel(q, k, v):
    from compile.kernels.window_attn import window_attention

    return window_attention(q, k, v, window=WIN, bq=64)


ARTIFACTS = {
    # -- full layers (E2E examples run these) --------------------------------
    "gcn_layer": (
        model.gcn_layer,
        GRAPH_ARGS + [("x", _s((V, F))), ("theta", _s((F, F)))],
    ),
    "gin_layer": (
        model.gin_layer,
        GRAPH_ARGS
        + [
            ("x", _s((V, F))),
            ("w1", _s((F, F))),
            ("b1", _s((F,))),
            ("w2", _s((F, F))),
            ("b2", _s((F,))),
        ],
    ),
    "transformer_layer": (
        functools.partial(model.transformer_layer, heads=HEADS, window=WIN),
        [
            ("x", _s((SEQ, DM))),
            ("wq", _s((DM, DM))),
            ("wk", _s((DM, DM))),
            ("wv", _s((DM, DM))),
            ("wo", _s((DM, DM))),
            ("w1", _s((DM, DFF))),
            ("b1", _s((DFF,))),
            ("w2", _s((DFF, DM))),
            ("b2", _s((DM,))),
            ("g1", _s((DM,))),
            ("be1", _s((DM,))),
            ("g2", _s((DM,))),
            ("be2", _s((DM,))),
        ],
    ),
    # -- single kernels (pipeline stages execute these) ----------------------
    "spmm": (spmm_kernel, GRAPH_ARGS + [("x", _s((V, F)))]),
    "gemm": (gemm_kernel, [("a", _s((V, F))), ("b", _s((F, F)))]),
    "gin_mlp": (
        model.gin_mlp,
        [
            ("y", _s((V, F))),
            ("w1", _s((F, F))),
            ("b1", _s((F,))),
            ("w2", _s((F, F))),
            ("b2", _s((F,))),
        ],
    ),
    "window_attn": (
        wattn_kernel,
        [
            ("q", _s((HEADS, SEQ, DM // HEADS))),
            ("k", _s((HEADS, SEQ, DM // HEADS))),
            ("v", _s((HEADS, SEQ, DM // HEADS))),
        ],
    ),
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(name: str):
    fn, args = ARTIFACTS[name]
    specs = [spec for _, spec in args]

    def tupled(*xs):
        return (fn(*xs),)

    lowered = jax.jit(tupled).lower(*specs)
    out_shape = jax.eval_shape(fn, *specs)
    return to_hlo_text(lowered), out_shape


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output dir")
    parser.add_argument(
        "--only", nargs="*", default=None, help="subset of artifact names"
    )
    ns = parser.parse_args()
    os.makedirs(ns.out, exist_ok=True)

    manifest = {"artifacts": {}}
    names = ns.only or list(ARTIFACTS)
    for name in names:
        text, out_shape = lower_artifact(name)
        fname = f"{name}.hlo.txt"
        path = os.path.join(ns.out, fname)
        with open(path, "w") as f:
            f.write(text)
        _, args = ARTIFACTS[name]
        manifest["artifacts"][name] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {
                    "name": arg_name,
                    "shape": list(spec.shape),
                    "dtype": str(spec.dtype),
                }
                for arg_name, spec in args
            ],
            "output": {
                "shape": list(out_shape.shape),
                "dtype": str(out_shape.dtype),
            },
        }
        print(f"wrote {path} ({len(text)} chars)")

    manifest["constants"] = {
        "graph": {"V": V, "F": F, "NRT": NRT, "ELL": ELL, "TM": TM, "TK": TK},
        "transformer": {
            "SEQ": SEQ,
            "DM": DM,
            "HEADS": HEADS,
            "DFF": DFF,
            "WIN": WIN,
        },
    }
    with open(os.path.join(ns.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(ns.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
