"""L2 — JAX compute graphs of the paper's two case-study workloads.

These are the *numerical* definitions of the workloads DYPE schedules:

* GCN layer (Eq 1):  X' = Â X Θ       → SpMM then GEMM.
* GIN layer (Eq 2):  X' = MLP(A' X)    → SpMM then an n-layer MLP (GEMMs).
* Transformer layer with sliding-window attention (Eqs 3,5,6):
  QKV projections (GEMM) → banded attention (fused SDDMM+softmax+SpMM,
  the L1 ``window_attention`` kernel) → output projection, FFN, residuals,
  LayerNorm.

Each function composes the L1 Pallas kernels so the whole layer lowers
into a single HLO module (``aot.py``).  Python never runs at serving time:
the Rust coordinator executes the lowered artifacts via PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.gemm import gemm
from compile.kernels.spmm import spmm
from compile.kernels.window_attn import window_attention


def layernorm(x, gamma, beta, eps: float = 1e-5):
    """LayerNorm over the last axis (regular op, stays in plain jnp)."""
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def gcn_layer(blocks, indices, x, theta):
    """One GCN layer: Y = Â·X (SpMM), X' = ReLU(Y·Θ) (GEMM).

    ``Â`` (degree-normalized adjacency with self-loops) arrives already
    factored into block-ELL ``(blocks, indices)`` — the paper pre-loads the
    static graph onto devices (§II-B data-partition strategy).
    """
    y = spmm(blocks, indices, x)
    return jax.nn.relu(gemm(y, theta))


def gin_layer(blocks, indices, x, w1, b1, w2, b2):
    """One GIN layer: X' = MLP(A'·X) with a 2-layer MLP (2 GEMMs)."""
    y = spmm(blocks, indices, x)
    h = jax.nn.relu(gemm(y, w1) + b1)
    return gemm(h, w2) + b2


def gin_mlp(y, w1, b1, w2, b2):
    """The dense tail of a GIN layer alone (a pipeline stage candidate)."""
    h = jax.nn.relu(gemm(y, w1) + b1)
    return gemm(h, w2) + b2


def transformer_layer(
    x, wq, wk, wv, wo, w1, b1, w2, b2, g1, be1, g2, be2, *, heads: int, window: int
):
    """One transformer layer with sliding-window attention.

    Args:
        x: ``(seq, d_model)`` activations.
        wq/wk/wv/wo: ``(d_model, d_model)`` projection weights.
        w1, b1, w2, b2: FFN weights ``(d_model, d_ff)`` / ``(d_ff, d_model)``.
        g1, be1, g2, be2: LayerNorm parameters ``(d_model,)``.
        heads: attention head count (d_model % heads == 0).
        window: sliding-window width (Eq 6 band).
    """
    seq, d_model = x.shape
    dh = d_model // heads

    def split(t):  # (seq, d_model) -> (heads, seq, dh)
        return t.reshape(seq, heads, dh).transpose(1, 0, 2)

    q = split(gemm(x, wq))
    k = split(gemm(x, wk))
    v = split(gemm(x, wv))
    z = window_attention(q, k, v, window=window, bq=min(128, window))
    z = z.transpose(1, 0, 2).reshape(seq, d_model)
    attn_out = gemm(z, wo)
    h = layernorm(x + attn_out, g1, be1)
    ffn = gemm(jax.nn.relu(gemm(h, w1) + b1), w2) + b2
    return layernorm(h + ffn, g2, be2)
