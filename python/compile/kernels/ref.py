"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has its semantics defined here, in plain
``jax.numpy`` with no Pallas involvement.  pytest asserts
``assert_allclose(kernel(...), ref(...))`` — this file is the CORE
correctness signal for layer 1.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul: ``(m, k) @ (k, n) -> (m, n)`` in f32."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def spmm_ref(
    blocks: jnp.ndarray, indices: jnp.ndarray, b: jnp.ndarray, k: int
) -> jnp.ndarray:
    """SpMM oracle: densify the block-ELL operand, then matmul.

    Args:
        blocks:  ``(nrt, ell, tm, tk)`` value blocks.
        indices: ``(nrt, ell)`` K-block indices.
        b:       ``(k, n)`` dense matrix.
        k:       logical K dimension of the sparse matrix.
    """
    nrt, ell, tm, tk = blocks.shape
    m = nrt * tm
    a = jnp.zeros((m, k), dtype=jnp.float32)
    for rt in range(nrt):
        for s in range(ell):
            c0 = indices[rt, s] * tk
            row = jnp.arange(tm) + rt * tm
            col = jnp.arange(tk) + c0
            a = a.at[row[:, None], col[None, :]].add(blocks[rt, s])
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def window_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, window: int
) -> jnp.ndarray:
    """Sliding-window attention oracle.

    Token ``i`` attends to tokens ``j`` with ``|i - j| <= window // 2``
    (symmetric Longformer/BigBird-style band; the paper's Eq (6) MASK).

    Args:
        q, k, v: ``(heads, seq, dim)``.
        window:  total band width (even).
    """
    h, s, d = q.shape
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(d))
    pos = jnp.arange(s)
    band = jnp.abs(pos[:, None] - pos[None, :]) <= window // 2
    scores = jnp.where(band[None, :, :], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def layernorm_ref(
    x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    """LayerNorm over the last axis."""
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
