"""Sparse-matrix container formats shared by the Pallas kernels and oracles.

The paper's FPGA SpMM (customized Sextans) streams CSR over 640 MAC units.
The TPU-shaped re-expression (see DESIGN.md §Hardware-Adaptation) keeps the
dense operand resident in VMEM and streams the *sparse structure* as a
block-ELL layout: rows are grouped into tiles of ``tm`` rows, the K
dimension into blocks of ``tk`` columns, and each row-tile stores a padded
list (``ell_width`` slots) of non-empty K-block indices plus the dense
``(tm, tk)`` value block for each slot.  Padding slots carry index 0 and an
all-zero value block, so the kernel needs no branches.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BlockEll:
    """Block-ELL sparse matrix of logical shape ``(m, k)``.

    Attributes:
        blocks:  ``(num_row_tiles, ell_width, tm, tk)`` float32 value blocks.
        indices: ``(num_row_tiles, ell_width)`` int32 K-block indices
                 (padding slots hold 0 and a zero value block).
        m, k:    logical dense shape.
        tm, tk:  tile sizes (rows per tile, cols per K-block).
    """

    blocks: np.ndarray
    indices: np.ndarray
    m: int
    k: int
    tm: int
    tk: int

    @property
    def num_row_tiles(self) -> int:
        return self.blocks.shape[0]

    @property
    def ell_width(self) -> int:
        return self.blocks.shape[1]

    def to_dense(self) -> np.ndarray:
        """Densify — the reference semantics of the format."""
        a = np.zeros((self.m, self.k), dtype=np.float32)
        for rt in range(self.num_row_tiles):
            r0 = rt * self.tm
            for s in range(self.ell_width):
                c0 = int(self.indices[rt, s]) * self.tk
                # Padding slots are all-zero blocks; += keeps them harmless
                # even when several padding slots alias K-block 0.
                a[r0 : r0 + self.tm, c0 : c0 + self.tk] += self.blocks[rt, s]
        return a

    @property
    def nnz_blocks(self) -> int:
        """Number of non-padding (non-zero) blocks."""
        return int((np.abs(self.blocks).sum(axis=(2, 3)) > 0).sum())


def dense_to_block_ell(
    a: np.ndarray, tm: int, tk: int, ell_width: int | None = None
) -> BlockEll:
    """Convert a dense ``(m, k)`` matrix to block-ELL.

    ``m`` must be divisible by ``tm`` and ``k`` by ``tk``.  If ``ell_width``
    is None it is set to the max number of non-empty K-blocks over all row
    tiles.  Raises if any row tile has more non-empty blocks than
    ``ell_width`` (lossy conversion is never silent).
    """
    m, k = a.shape
    if m % tm or k % tk:
        raise ValueError(f"shape ({m},{k}) not divisible by tile ({tm},{tk})")
    nrt, nkb = m // tm, k // tk
    tiles = a.reshape(nrt, tm, nkb, tk).transpose(0, 2, 1, 3)  # (nrt,nkb,tm,tk)
    nonempty = np.abs(tiles).sum(axis=(2, 3)) > 0  # (nrt, nkb)
    widths = nonempty.sum(axis=1)
    if ell_width is None:
        ell_width = max(int(widths.max()), 1)
    elif int(widths.max()) > ell_width:
        raise ValueError(
            f"row tile has {int(widths.max())} non-empty blocks > ell_width {ell_width}"
        )
    blocks = np.zeros((nrt, ell_width, tm, tk), dtype=np.float32)
    indices = np.zeros((nrt, ell_width), dtype=np.int32)
    for rt in range(nrt):
        slot = 0
        for kb in range(nkb):
            if nonempty[rt, kb]:
                blocks[rt, slot] = tiles[rt, kb]
                indices[rt, slot] = kb
                slot += 1
    return BlockEll(blocks=blocks, indices=indices, m=m, k=k, tm=tm, tk=tk)


def random_block_ell(
    m: int,
    k: int,
    tm: int,
    tk: int,
    ell_width: int,
    fill: float = 1.0,
    seed: int = 0,
) -> BlockEll:
    """Random block-ELL matrix: each row tile gets ``ell_width`` distinct
    random K-block indices; a ``fill`` fraction of slots is populated with
    random values (the rest stay zero-padding).
    """
    rng = np.random.default_rng(seed)
    nrt, nkb = m // tm, k // tk
    if ell_width > nkb:
        raise ValueError(f"ell_width {ell_width} > number of K blocks {nkb}")
    blocks = np.zeros((nrt, ell_width, tm, tk), dtype=np.float32)
    indices = np.zeros((nrt, ell_width), dtype=np.int32)
    for rt in range(nrt):
        cols = rng.choice(nkb, size=ell_width, replace=False)
        nfill = max(1, int(round(fill * ell_width)))
        for s in range(nfill):
            indices[rt, s] = cols[s]
            blocks[rt, s] = rng.standard_normal((tm, tk), dtype=np.float32)
    return BlockEll(blocks=blocks, indices=indices, m=m, k=k, tm=tm, tk=tk)
