"""MXU-tiled dense GEMM Pallas kernel.

The regular-compute baseline of the paper (rocblas_sgemm on GPU, [31] on
FPGA).  Expressed for a TPU-shaped machine: ``(bm, bn)`` output tiles
resident in VMEM, K streamed in ``bk`` slabs, f32 accumulation on the MXU.
Grid = (M/bm, N/bn, K/bk) with the K axis innermost so the output block
revision stays in VMEM across the accumulation (the BlockSpec index_map for
the output ignores the K grid axis).

Run with ``interpret=True`` — real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute (see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jnp.ndarray:
    """``(m, k) @ (k, n) -> (m, n)`` f32 matmul.

    Shapes must be divisible by the block sizes; the L2 models choose
    MXU-aligned dimensions so this never pads.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    # Clamp block sizes to the problem: small model dims (e.g. 64-wide
    # features) should not require callers to re-derive tile shapes.
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"({m},{k},{n}) not divisible by blocks ({bm},{bk},{bn})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
