"""Block-ELL SpMM Pallas kernel — the paper's sparse hot-spot (Y = Â·X).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's FPGA
design (customized Sextans, 640 MACs @ 215 MHz) streams CSR non-zeros
through a scalar-MAC array.  A TPU-shaped machine wants *dense tiles on the
MXU*, so the sparse structure is re-expressed as block-ELL (see
``formats.py``): the kernel walks one row-tile per grid step, and for each
of the ``ell_width`` slots gathers the referenced K-block rows of the dense
operand from the VMEM-resident copy and issues a dense ``(tm, tk) @
(tk, n)`` matmul.  Padding slots index block 0 with an all-zero value
block, contributing exactly zero — no branches on the hot path.

The HBM↔VMEM schedule Sextans expressed with streaming FIFOs is expressed
here with BlockSpecs: value blocks and indices are tiled per grid step; the
dense operand is kept whole (its reuse across row tiles is the whole point
of keeping it resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(blocks_ref, indices_ref, b_ref, o_ref, *, ell_width: int, tk: int):
    acc = jnp.zeros_like(o_ref)
    for s in range(ell_width):  # static unroll: ell_width is a format param
        kb = indices_ref[0, s]
        b_slab = b_ref[pl.dslice(kb * tk, tk), :]  # gather (tk, n) from VMEM
        acc += jnp.dot(
            blocks_ref[0, s], b_slab, preferred_element_type=jnp.float32
        )
    o_ref[...] = acc


@jax.jit
def spmm(
    blocks: jnp.ndarray, indices: jnp.ndarray, b: jnp.ndarray
) -> jnp.ndarray:
    """Sparse(block-ELL) × dense matmul.

    Args:
        blocks:  ``(nrt, ell, tm, tk)`` f32 value blocks.
        indices: ``(nrt, ell)`` int32 K-block indices.
        b:       ``(k, n)`` f32 dense matrix, ``k % tk == 0``.

    Returns:
        ``(nrt * tm, n)`` f32.
    """
    nrt, ell, tm, tk = blocks.shape
    k, n = b.shape
    assert k % tk == 0, f"k={k} not divisible by tk={tk}"
    kernel = functools.partial(_spmm_kernel, ell_width=ell, tk=tk)
    return pl.pallas_call(
        kernel,
        grid=(nrt,),
        in_specs=[
            pl.BlockSpec((1, ell, tm, tk), lambda rt: (rt, 0, 0, 0)),
            pl.BlockSpec((1, ell), lambda rt: (rt, 0)),
            pl.BlockSpec((k, n), lambda rt: (0, 0)),  # resident dense operand
        ],
        out_specs=pl.BlockSpec((tm, n), lambda rt: (rt, 0)),
        out_shape=jax.ShapeDtypeStruct((nrt * tm, n), jnp.float32),
        interpret=True,
    )(blocks, indices, b)
