"""Banded (sliding-window) flash-attention Pallas kernel.

The paper extends SWAT [6]: sliding-window attention turns ``S = Q·Kᵀ``
into an SDDMM and ``Z = S'·V`` into an SpMM, which SWAT pipelines
row-stationary on an FPGA @421 MHz.  The TPU-shaped re-expression fuses
both sparse products and the softmax into ONE VMEM-resident banded
flash-attention kernel (DESIGN.md §Hardware-Adaptation): grid over
(head, query-block); each step loads only the K/V blocks intersecting the
band, computes QKᵀ on the MXU, applies the in-band mask, and online-softmax
accumulates.  Out-of-band work is never materialized — the static band
sparsity the paper exploits with FPGA FIFOs is exploited here by the
BlockSpec/dslice schedule.

Semantics (matches ``ref.window_attention_ref``): token ``i`` attends to
tokens ``j`` with ``|i - j| <= window // 2``.

K and V are pre-padded with ``window // 2`` zero rows on each side by the
jitted wrapper so every ``pl.dslice`` load is in-bounds and distinct —
masking then uses true token positions (pads fall outside the band and the
sequence, contributing -inf scores).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _wattn_kernel(
    q_ref, k_ref, v_ref, o_ref, *, seq: int, window: int, bq: int, dim: int
):
    half = window // 2
    nkb = window // bq + 1  # key blocks covering [q0-half, q0+bq-1+half]
    qi = pl.program_id(1)
    q0 = qi * bq  # first query position of this block
    q = q_ref[0] * (1.0 / (dim**0.5))  # (bq, d)
    qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bq), 0)

    m_i = jnp.full((bq, 1), NEG_INF, dtype=jnp.float32)
    l_i = jnp.zeros((bq, 1), dtype=jnp.float32)
    acc = jnp.zeros((bq, dim), dtype=jnp.float32)

    for s in range(nkb):  # static unroll: band width is a model constant
        # Start of this key block in *padded* coordinates (>= 0 always).
        start_pad = q0 + s * bq
        k_blk = k_ref[0, pl.dslice(start_pad, bq), :]  # (bq, d)
        v_blk = v_ref[0, pl.dslice(start_pad, bq), :]
        # True token positions of the loaded keys.
        kpos = (start_pad - half) + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bq), 1
        )
        scores = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)
        in_band = jnp.abs(qpos - kpos) <= half
        in_seq = (kpos >= 0) & (kpos < seq)
        scores = jnp.where(in_band & in_seq, scores, NEG_INF)
        # Online softmax update.
        m_new = jnp.maximum(m_i, scores.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(scores - m_new)
        l_i = l_i * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, v_blk, preferred_element_type=jnp.float32)
        m_i = m_new

    o_ref[0] = acc / l_i


@functools.partial(jax.jit, static_argnames=("window", "bq"))
def window_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    window: int,
    bq: int = 128,
) -> jnp.ndarray:
    """Sliding-window attention over ``(heads, seq, dim)`` inputs.

    Constraints: ``window`` even, ``bq | window``, ``bq | seq`` — the L2
    models pick compliant shapes (they are model hyper-parameters, exactly
    as in SWAT's BigBird setting).
    """
    h, s, d = q.shape
    assert window % 2 == 0 and window % bq == 0 and s % bq == 0, (
        f"window={window} bq={bq} seq={s} violate alignment"
    )
    half = window // 2
    pad = ((0, 0), (half, half), (0, 0))
    k_pad = jnp.pad(k, pad)
    v_pad = jnp.pad(v, pad)
    s_pad = s + window
    kernel = functools.partial(
        _wattn_kernel, seq=s, window=window, bq=bq, dim=d
    )
    return pl.pallas_call(
        kernel,
        grid=(h, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hh, qi: (hh, qi, 0)),
            pl.BlockSpec((1, s_pad, d), lambda hh, qi: (hh, 0, 0)),
            pl.BlockSpec((1, s_pad, d), lambda hh, qi: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda hh, qi: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), jnp.float32),
        interpret=True,
    )(q, k_pad, v_pad)
